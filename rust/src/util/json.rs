//! Minimal JSON: parse + emit.  Used for `manifest.json` (written by the
//! Python AOT side) and for machine-readable experiment reports.
//!
//! Supports the full JSON grammar except `\u` surrogate pairs outside the
//! BMP (not needed by any artifact we read).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.  Numbers are kept as `f64` (the manifest only contains
/// integers well within the exact range).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset for debuggability.
#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: s.as_bytes(),
            pos: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors (panic-free, Option-returning) --------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// `obj[key]` as usize, with a descriptive panic for malformed
    /// manifests (these are build artifacts, not user input).
    pub fn req_usize(&self, key: &str) -> usize {
        self.get(key)
            .and_then(Json::as_usize)
            .unwrap_or_else(|| panic!("manifest field `{key}` missing or not a number"))
    }

    pub fn req_str(&self, key: &str) -> &str {
        self.get(key)
            .and_then(Json::as_str)
            .unwrap_or_else(|| panic!("manifest field `{key}` missing or not a string"))
    }

    pub fn req_arr(&self, key: &str) -> &[Json] {
        self.get(key)
            .and_then(Json::as_arr)
            .unwrap_or_else(|| panic!("manifest field `{key}` missing or not an array"))
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn ws(&mut self) {
        while self.pos < self.b.len() && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r') {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{s}`")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            _ => self.number(),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let val = self.value()?;
            out.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.pos += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.pos + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.pos..self.pos + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                _ => {
                    // Collect the full UTF-8 sequence starting at c.
                    let start = self.pos - 1;
                    let len = utf8_len(c);
                    self.pos = start + len;
                    if self.pos > self.b.len() {
                        return Err(self.err("truncated utf8"));
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.pos])
                            .map_err(|_| self.err("invalid utf8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E'))
            .unwrap_or(false)
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

/// Convenience builders for report emission.
impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let src = r#"{"a": [1, 2.5, -3], "b": {"c": "x\ny", "d": true, "e": null}}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"n": 42, "s": "hi", "a": [1,2]}"#).unwrap();
        assert_eq!(v.req_usize("n"), 42);
        assert_eq!(v.req_str("s"), "hi");
        assert_eq!(v.req_arr("a").len(), 2);
        assert!(v.get("missing").is_none());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("[1] x").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""é""#).unwrap();
        assert_eq!(v.as_str(), Some("é"));
    }
}
