//! Tiny argv parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.
//! Unknown flags are collected so callers can reject them with a usage
//! message.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse raw argv (excluding the program name).
    /// `value_opts` lists the option names that consume a value.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I, value_opts: &[&str]) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if value_opts.contains(&body) {
                    let v = it.next().unwrap_or_default();
                    out.opts.insert(body.to_string(), v);
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(String::as_str)
    }

    pub fn opt_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.opt(name).unwrap_or(default)
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.opt(name)
            .map(|v| {
                v.parse()
                    .unwrap_or_else(|_| panic!("--{name} expects an integer, got `{v}`"))
            })
            .unwrap_or(default)
    }

    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.opt(name)
            .map(|v| {
                v.parse()
                    .unwrap_or_else(|_| panic!("--{name} expects an integer, got `{v}`"))
            })
            .unwrap_or(default)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.opt(name)
            .map(|v| {
                v.parse()
                    .unwrap_or_else(|_| panic!("--{name} expects a number, got `{v}`"))
            })
            .unwrap_or(default)
    }

    /// Comma-separated number list (`--rates 200,500,1000`); absent or
    /// empty falls back to `default`.
    pub fn f64_list_or(&self, name: &str, default: &[f64]) -> Vec<f64> {
        match self.opt(name) {
            None | Some("") => default.to_vec(),
            Some(v) => v
                .split(',')
                .map(|s| {
                    s.trim().parse().unwrap_or_else(|_| {
                        panic!("--{name} expects comma-separated numbers, got `{v}`")
                    })
                })
                .collect(),
        }
    }

    /// The conventional `--threads N` plumb-through: 0 or absent means
    /// `default` (callers pass the pool's autodetected width).
    pub fn threads_or(&self, default: usize) -> usize {
        match self.usize_or("threads", 0) {
            0 => default,
            n => n,
        }
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Flags that nobody consumed (for strict CLIs).
    pub fn unknown_flags<'a>(&'a self, known: &[&str]) -> Vec<&'a str> {
        self.flags
            .iter()
            .filter(|f| !known.contains(&f.as_str()))
            .map(String::as_str)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_mixed() {
        let a = Args::parse(
            sv(&["run", "--steps", "100", "--fast", "--out=x.json", "extra"]),
            &["steps"],
        );
        assert_eq!(a.positional, vec!["run", "extra"]);
        assert_eq!(a.usize_or("steps", 0), 100);
        assert!(a.flag("fast"));
        assert_eq!(a.opt("out"), Some("x.json"));
    }

    #[test]
    fn defaults() {
        let a = Args::parse(sv(&[]), &[]);
        assert_eq!(a.usize_or("steps", 7), 7);
        assert_eq!(a.f64_or("lr", 0.5), 0.5);
        assert!(!a.flag("fast"));
    }

    #[test]
    fn unknown_flags_detected() {
        let a = Args::parse(sv(&["--weird"]), &[]);
        assert_eq!(a.unknown_flags(&["fast"]), vec!["weird"]);
    }

    #[test]
    fn threads_plumb_through() {
        let a = Args::parse(sv(&["--threads", "6"]), &["threads"]);
        assert_eq!(a.threads_or(2), 6);
        let b = Args::parse(sv(&[]), &["threads"]);
        assert_eq!(b.threads_or(2), 2);
        let c = Args::parse(sv(&["--threads=0"]), &["threads"]);
        assert_eq!(c.threads_or(3), 3);
    }
}
