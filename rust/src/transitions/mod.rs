//! Partial-sum transition grouping and transition statistics (paper §3.1.1).
//!
//! The 22-bit accumulator's transition space (2^22 × 2^22) is collapsed
//! into 50 groups — 10 uniform MSB-position bins × 5 Hamming-weight bins —
//! chosen because MSB position tracks carry-propagation depth and Hamming
//! distance tracks toggled-bit count (validated in Fig. 2 / the
//! `fig2_grouping_metrics` bench).

pub mod group;
pub mod histogram;

pub use group::{group_of, hamming_weight, msb_position, stability_ratio, Grouping, N_GROUPS};
pub use histogram::{ActTransHist, PsumGroupHist};
