//! Transition histograms + probabilistic trace synthesis (paper §3.1.2).
//!
//! Per layer we estimate two independent empirical distributions from
//! calibration traces produced by the Rust int8 engine / systolic
//! scheduler:
//!
//! * [`ActTransHist`] — activation transitions: a 256×256 count matrix
//!   over consecutive int8 activation codes seen by a PE.
//! * [`PsumGroupHist`] — partial-sum transitions collapsed onto the
//!   50×50 group-pair matrix of [`super::group`].
//!
//! Synthetic MAC input traces are then re-sampled from these histograms
//! (activation chain via the conditional row distribution; partial sums
//! by drawing a representative pattern per group).

use crate::mac::ACC_BITS;
use crate::transitions::group::{group_of, to_bits, N_GROUPS};
use crate::util::rng::Xoshiro256;

/// 256×256 activation transition counts; code index = `code + 128`.
#[derive(Clone)]
pub struct ActTransHist {
    pub counts: Vec<u32>, // [256 * 256], row = from, col = to
    pub total: u64,
}

impl Default for ActTransHist {
    fn default() -> Self {
        Self::new()
    }
}

impl ActTransHist {
    pub fn new() -> Self {
        Self {
            counts: vec![0; 256 * 256],
            total: 0,
        }
    }

    #[inline]
    pub fn idx(from: i32, to: i32) -> usize {
        debug_assert!((-128..=127).contains(&from) && (-128..=127).contains(&to));
        ((from + 128) as usize) * 256 + (to + 128) as usize
    }

    #[inline]
    pub fn record(&mut self, from: i32, to: i32) {
        self.counts[Self::idx(from, to)] += 1;
        self.total += 1;
    }

    /// Record a whole code stream.
    pub fn record_stream(&mut self, codes: &[i8]) {
        for w in codes.windows(2) {
            self.record(w[0] as i32, w[1] as i32);
        }
    }

    pub fn prob(&self, from: i32, to: i32) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        self.counts[Self::idx(from, to)] as f64 / self.total as f64
    }

    /// Marginal distribution of the `from` code.
    pub fn from_marginal(&self) -> Vec<f64> {
        let mut m = vec![0.0f64; 256];
        for f in 0..256 {
            let row = &self.counts[f * 256..(f + 1) * 256];
            m[f] = row.iter().map(|&c| c as f64).sum();
        }
        let t = self.total.max(1) as f64;
        m.iter_mut().for_each(|v| *v /= t);
        m
    }

    /// Sample an activation code chain of length `n` following the
    /// empirical transition kernel (falls back to the marginal when a row
    /// is empty).  Codes returned in `[-128, 127]`.
    pub fn sample_chain(&self, n: usize, rng: &mut Xoshiro256) -> Vec<i32> {
        if n == 0 {
            return Vec::new();
        }
        let marginal = self.from_marginal();
        let mut out = Vec::with_capacity(n);
        let mut cur = rng.weighted(&marginal) as i32 - 128;
        out.push(cur);
        let mut row_buf = vec![0.0f64; 256];
        for _ in 1..n {
            let row = &self.counts[((cur + 128) as usize) * 256..((cur + 128) as usize + 1) * 256];
            let row_total: u64 = row.iter().map(|&c| c as u64).sum();
            let next = if row_total == 0 {
                rng.weighted(&marginal) as i32 - 128
            } else {
                for (i, &c) in row.iter().enumerate() {
                    row_buf[i] = c as f64;
                }
                rng.weighted(&row_buf) as i32 - 128
            };
            out.push(next);
            cur = next;
        }
        out
    }

    /// Sparsity: fraction of transition mass with `to == 0` (ReLU layers
    /// show high values here — the layer-to-layer variability the paper's
    /// Fig. 3 visualizes).
    pub fn zero_fraction(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let mut z = 0u64;
        for f in 0..256 {
            z += self.counts[f * 256 + 128] as u64;
        }
        z as f64 / self.total as f64
    }

    /// Downsample to a `bins`×`bins` heatmap (for Fig. 3 rendering).
    pub fn heatmap(&self, bins: usize) -> Vec<f64> {
        let mut hm = vec![0.0f64; bins * bins];
        for f in 0..256 {
            for t in 0..256 {
                let c = self.counts[f * 256 + t];
                if c > 0 {
                    hm[(f * bins / 256) * bins + (t * bins / 256)] += c as f64;
                }
            }
        }
        let total = self.total.max(1) as f64;
        hm.iter_mut().for_each(|v| *v /= total);
        hm
    }
}

/// 50×50 grouped partial-sum transition counts, plus one representative
/// reservoir pattern per group for trace synthesis.
#[derive(Clone)]
pub struct PsumGroupHist {
    pub counts: Vec<u32>, // [N_GROUPS * N_GROUPS]
    pub total: u64,
    /// Up to `RESERVOIR` observed raw patterns per group.
    reservoirs: Vec<Vec<u32>>,
    seen_per_group: Vec<u64>,
}

const RESERVOIR: usize = 32;

impl Default for PsumGroupHist {
    fn default() -> Self {
        Self::new()
    }
}

impl PsumGroupHist {
    pub fn new() -> Self {
        Self {
            counts: vec![0; N_GROUPS * N_GROUPS],
            total: 0,
            reservoirs: vec![Vec::new(); N_GROUPS],
            seen_per_group: vec![0; N_GROUPS],
        }
    }

    /// Record a signed psum transition.
    pub fn record(&mut self, from: i32, to: i32, rng: &mut Xoshiro256) {
        let fb = to_bits(from);
        let tb = to_bits(to);
        let gf = group_of(fb);
        let gt = group_of(tb);
        self.counts[gf * N_GROUPS + gt] += 1;
        self.total += 1;
        for (g, bits) in [(gf, fb), (gt, tb)] {
            self.seen_per_group[g] += 1;
            let res = &mut self.reservoirs[g];
            if res.len() < RESERVOIR {
                res.push(bits);
            } else {
                // Reservoir sampling keeps representatives unbiased.
                let j = rng.below(self.seen_per_group[g]) as usize;
                if j < RESERVOIR {
                    res[j] = bits;
                }
            }
        }
    }

    /// Record a whole signed psum stream.
    pub fn record_stream(&mut self, psums: &[i32], rng: &mut Xoshiro256) {
        for w in psums.windows(2) {
            self.record(w[0], w[1], rng);
        }
    }

    pub fn prob(&self, gf: usize, gt: usize) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        self.counts[gf * N_GROUPS + gt] as f64 / self.total as f64
    }

    /// Draw a representative raw pattern for a group (falls back to a
    /// synthetic member when the reservoir is empty).
    pub fn representative(&self, g: usize, rng: &mut Xoshiro256) -> u32 {
        let res = &self.reservoirs[g];
        if !res.is_empty() {
            return res[rng.below(res.len() as u64) as usize];
        }
        synth_member(g, rng)
    }

    /// Sample a psum value chain of length `n`: group chain follows the
    /// empirical group-pair kernel; raw patterns come from reservoirs.
    pub fn sample_chain(&self, n: usize, rng: &mut Xoshiro256) -> Vec<i32> {
        if n == 0 {
            return Vec::new();
        }
        // Marginal over `from` groups.
        let mut marg = vec![0.0f64; N_GROUPS];
        for g in 0..N_GROUPS {
            marg[g] = self.counts[g * N_GROUPS..(g + 1) * N_GROUPS]
                .iter()
                .map(|&c| c as f64)
                .sum();
        }
        let mut out = Vec::with_capacity(n);
        let mut gcur = rng.weighted(&marg);
        out.push(from_bits(self.representative(gcur, rng)));
        let mut row_buf = vec![0.0f64; N_GROUPS];
        for _ in 1..n {
            let row = &self.counts[gcur * N_GROUPS..(gcur + 1) * N_GROUPS];
            let row_total: u32 = row.iter().sum();
            let gnext = if row_total == 0 {
                rng.weighted(&marg)
            } else {
                for (i, &c) in row.iter().enumerate() {
                    row_buf[i] = c as f64;
                }
                rng.weighted(&row_buf)
            };
            out.push(from_bits(self.representative(gnext, rng)));
            gcur = gnext;
        }
        out
    }
}

/// Signed value from a raw 22-bit pattern.
#[inline]
pub fn from_bits(bits: u32) -> i32 {
    ((bits as i32) << (32 - ACC_BITS)) >> (32 - ACC_BITS)
}

/// Construct *some* member of group `g` (used before any data is seen):
/// pick an MSB and Hamming weight consistent with the bin, then scatter
/// the remaining ones below the MSB.
fn synth_member(g: usize, rng: &mut Xoshiro256) -> u32 {
    use crate::transitions::group::{HW_BINS, MSB_BINS};
    let msb_bin = g / HW_BINS;
    let hw_bin = g % HW_BINS;
    // Invert the uniform binning: smallest msb with (msb*MSB_BINS)/(B+1)
    // == msb_bin is ceil(msb_bin*(B+1)/MSB_BINS).
    let msb = ((msb_bin * (ACC_BITS + 1) + MSB_BINS - 1) / MSB_BINS).min(ACC_BITS);
    if msb == 0 {
        return 0;
    }
    let hw_target = ((hw_bin * (ACC_BITS + 1) + HW_BINS - 1) / HW_BINS)
        .max(1)
        .min(msb);
    let mut v = 1u32 << (msb - 1);
    let mut ones = 1;
    let mut guard = 0;
    while ones < hw_target && guard < 200 {
        let pos = rng.below(msb as u64 - 1) as u32;
        if v & (1 << pos) == 0 {
            v |= 1 << pos;
            ones += 1;
        }
        guard += 1;
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn act_hist_records_and_samples() {
        let mut h = ActTransHist::new();
        // A deterministic 0 -> 5 -> 0 -> 5 ... stream.
        let stream: Vec<i8> = (0..100).map(|i| if i % 2 == 0 { 0 } else { 5 }).collect();
        h.record_stream(&stream);
        assert_eq!(h.total, 99);
        assert!(h.prob(0, 5) > 0.4);
        assert!(h.prob(5, 0) > 0.4);
        let mut rng = Xoshiro256::new(1);
        let chain = h.sample_chain(1000, &mut rng);
        // The chain must only visit {0, 5}.
        assert!(chain.iter().all(|&c| c == 0 || c == 5));
        // And alternate nearly always.
        let alternations = chain.windows(2).filter(|w| w[0] != w[1]).count();
        assert!(alternations > 900);
    }

    #[test]
    fn zero_fraction_tracks_relu_sparsity() {
        let mut h = ActTransHist::new();
        let stream: Vec<i8> = (0..1000).map(|i| if i % 4 == 0 { 7 } else { 0 }).collect();
        h.record_stream(&stream);
        assert!(h.zero_fraction() > 0.6);
    }

    #[test]
    fn psum_hist_roundtrip() {
        let mut rng = Xoshiro256::new(2);
        let mut h = PsumGroupHist::new();
        let stream: Vec<i32> = (0..2000)
            .map(|_| (rng.next_u64() & 0xFFFF) as i32 - 0x8000)
            .collect();
        h.record_stream(&stream, &mut rng);
        assert_eq!(h.total, 1999);
        let chain = h.sample_chain(500, &mut rng);
        assert_eq!(chain.len(), 500);
        // Sampled values stay in the 22-bit signed range.
        assert!(chain.iter().all(|&v| (-(1 << 21)..(1 << 21)).contains(&v)));
    }

    #[test]
    fn synth_member_hits_group() {
        let mut rng = Xoshiro256::new(3);
        for g in 0..N_GROUPS {
            let v = synth_member(g, &mut rng);
            // Member must be *near* the requested bins (exact for MSB bin).
            let got = crate::transitions::group::group_of(v);
            let msb_bin = got / crate::transitions::group::HW_BINS;
            assert!(
                msb_bin == g / crate::transitions::group::HW_BINS || v == 0,
                "g={g} v={v:#x} got={got}"
            );
        }
    }

    #[test]
    fn heatmap_mass_normalized() {
        let mut h = ActTransHist::new();
        let stream: Vec<i8> = (0..500).map(|i| (i % 7 - 3) as i8).collect();
        h.record_stream(&stream);
        let hm = h.heatmap(16);
        let mass: f64 = hm.iter().sum();
        assert!((mass - 1.0).abs() < 1e-9);
    }
}
