//! The two-stage MSB × Hamming-weight grouping of 22-bit partial sums.
//!
//! Stage 1: the MSB position (0..=22, where 0 = value 0) is uniformly
//! partitioned into [`MSB_BINS`] bins.  Stage 2: within an MSB bin,
//! values are split by Hamming weight into [`HW_BINS`] uniform bins
//! (weight range 0..=22).  Total [`N_GROUPS`] = 50 representative
//! clusters, exactly the paper's 10 × 5 scheme.
//!
//! Values are the raw 22-bit accumulator patterns (two's complement), so
//! the MSB of a negative value is high — matching what the adder's
//! carry chain actually sees.

use crate::mac::ACC_BITS;

pub const MSB_BINS: usize = 10;
pub const HW_BINS: usize = 5;
pub const N_GROUPS: usize = MSB_BINS * HW_BINS;

/// MSB position of the 22-bit pattern: 0 for value 0, else 1 + index of
/// the highest set bit (1..=22).
#[inline]
pub fn msb_position(psum_bits: u32) -> u32 {
    debug_assert!(psum_bits < (1 << ACC_BITS));
    32 - psum_bits.leading_zeros()
}

/// Hamming weight (number of set bits) of the 22-bit pattern.
#[inline]
pub fn hamming_weight(psum_bits: u32) -> u32 {
    psum_bits.count_ones()
}

/// Map a signed accumulator value to its raw 22-bit pattern.
#[inline]
pub fn to_bits(psum: i32) -> u32 {
    (psum as u32) & ((1 << ACC_BITS) - 1)
}

/// The grouping function: 22-bit pattern -> group id in `0..N_GROUPS`.
#[inline]
pub fn group_of(psum_bits: u32) -> usize {
    // MSB range 0..=22 -> 10 uniform bins.
    let msb = msb_position(psum_bits) as usize;
    let msb_bin = (msb * MSB_BINS) / (ACC_BITS + 1);
    // Hamming weight range 0..=22 -> 5 uniform bins.
    let hw = hamming_weight(psum_bits) as usize;
    let hw_bin = (hw * HW_BINS) / (ACC_BITS + 1);
    msb_bin * HW_BINS + hw_bin
}

/// A grouping scheme abstraction so ablations can swap partitions
/// (uniform vs alternatives) while the rest of the model is unchanged.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Grouping {
    /// The paper's uniform 10 × 5 MSB × HW partition.
    MsbHamming,
    /// MSB-only partition into 50 uniform bins (ablation).
    MsbOnly,
    /// Hamming-weight-only partition into 50 bins capped at 23 distinct
    /// weights (ablation).
    HammingOnly,
}

impl Grouping {
    pub fn group(&self, psum_bits: u32) -> usize {
        match self {
            Grouping::MsbHamming => group_of(psum_bits),
            Grouping::MsbOnly => {
                let msb = msb_position(psum_bits) as usize;
                (msb * N_GROUPS) / (ACC_BITS + 1)
            }
            Grouping::HammingOnly => {
                let hw = hamming_weight(psum_bits) as usize;
                (hw * N_GROUPS) / (ACC_BITS + 1)
            }
        }
    }
}

/// Grouping quality metric from the paper: variance of inter-group means
/// divided by mean intra-group variance, computed over per-sample scalar
/// costs (e.g. measured MAC energies) labeled with group ids.
///
/// Returns `f64::INFINITY` when all intra-group variances are zero and
/// the inter-group variance is positive (perfect separation).
pub fn stability_ratio(samples: &[(usize, f64)]) -> f64 {
    let mut sums = vec![0.0f64; N_GROUPS];
    let mut sqs = vec![0.0f64; N_GROUPS];
    let mut counts = vec![0usize; N_GROUPS];
    for &(g, v) in samples {
        sums[g] += v;
        sqs[g] += v * v;
        counts[g] += 1;
    }
    let mut means = Vec::new();
    let mut intra = Vec::new();
    for g in 0..N_GROUPS {
        if counts[g] < 2 {
            continue;
        }
        let n = counts[g] as f64;
        let mean = sums[g] / n;
        means.push(mean);
        intra.push((sqs[g] / n - mean * mean).max(0.0));
    }
    if means.len() < 2 {
        return 0.0;
    }
    let gm = means.iter().sum::<f64>() / means.len() as f64;
    let inter = means.iter().map(|m| (m - gm) * (m - gm)).sum::<f64>() / means.len() as f64;
    let mean_intra = intra.iter().sum::<f64>() / intra.len() as f64;
    if mean_intra == 0.0 {
        return if inter > 0.0 { f64::INFINITY } else { 0.0 };
    }
    inter / mean_intra
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn group_is_total_and_bounded() {
        // Property: every 22-bit pattern lands in a valid group
        // (sampled sweep + structured corners).
        let mut rng = crate::util::rng::Xoshiro256::new(5);
        for _ in 0..10_000 {
            let v = (rng.next_u64() & ((1 << ACC_BITS) - 1)) as u32;
            assert!(group_of(v) < N_GROUPS);
        }
        for v in [0u32, 1, (1 << ACC_BITS) - 1, 1 << 21, 0x2AAAAA] {
            assert!(group_of(v) < N_GROUPS);
        }
    }

    #[test]
    fn all_groups_reachable_enough() {
        // The uniform partition must spread mass: at least 40 of the 50
        // groups are hit by uniform random patterns + low-magnitude
        // values (some (low-MSB, high-HW) combos are impossible: HW can
        // never exceed MSB position).
        let mut seen = vec![false; N_GROUPS];
        let mut rng = crate::util::rng::Xoshiro256::new(6);
        for _ in 0..200_000 {
            let v = (rng.next_u64() & ((1 << ACC_BITS) - 1)) as u32;
            seen[group_of(v)] = true;
        }
        for m in 0..=21 {
            seen[group_of(1u32 << m)] = true;
            seen[group_of((1u32 << (m + 1)) - 1)] = true;
        }
        let n_seen = seen.iter().filter(|&&s| s).count();
        assert!(n_seen >= 30, "only {n_seen} groups reachable");
    }

    #[test]
    fn msb_and_hw_helpers() {
        assert_eq!(msb_position(0), 0);
        assert_eq!(msb_position(1), 1);
        assert_eq!(msb_position(1 << 21), 22);
        assert_eq!(hamming_weight(0b1011), 3);
        assert_eq!(to_bits(-1), (1 << ACC_BITS) - 1);
        assert_eq!(to_bits(5), 5);
    }

    #[test]
    fn sign_boundary_negatives_land_in_top_msb_bin() {
        // Two's-complement: every negative accumulator value has bit 21
        // set, so its MSB position is 22 and its MSB bin is the top one —
        // exactly what the adder's carry chain sees at the sign boundary.
        for v in [-1i32, -2, -5, -1000, -(1 << 20), -(1 << 21)] {
            let bits = to_bits(v);
            assert_eq!(msb_position(bits), ACC_BITS as u32, "v={v}");
            let g = group_of(bits);
            assert_eq!(g / HW_BINS, MSB_BINS - 1, "v={v} bits={bits:#x} g={g}");
        }
        // The sign boundary itself: -1 (all ones) vs 0 sit in opposite
        // corners of the partition.
        assert_eq!(group_of(to_bits(0)), 0);
        assert_eq!(group_of(to_bits(-1)), N_GROUPS - 1);
    }

    #[test]
    fn zero_value_is_its_own_group_corner() {
        // Value 0: MSB position 0, Hamming weight 0 -> group 0, and no
        // positive-magnitude pattern may share bin (0, 0) with it except
        // via the uniform binning of tiny values.
        assert_eq!(msb_position(0), 0);
        assert_eq!(hamming_weight(0), 0);
        assert_eq!(group_of(0), 0);
        // A zero *weight* stalls the accumulator: the psum transition is
        // p -> p, so all recorded mass must land on the group-pair
        // diagonal (g, g).
        let mut rng = crate::util::rng::Xoshiro256::new(9);
        let mut h = crate::transitions::histogram::PsumGroupHist::new();
        for p in [0i32, 7, -3, 1 << 12] {
            h.record(p, p, &mut rng);
        }
        assert_eq!(h.total, 4);
        for gf in 0..N_GROUPS {
            for gt in 0..N_GROUPS {
                if gf != gt {
                    assert_eq!(
                        h.counts[gf * N_GROUPS + gt],
                        0,
                        "stalled transition leaked off-diagonal ({gf}, {gt})"
                    );
                }
            }
        }
    }

    #[test]
    fn overflow_wraps_into_22_bits() {
        // The hardware accumulator wraps at 22 bits; to_bits must mask
        // identically so grouping sees the same pattern the adder holds.
        assert_eq!(to_bits(1 << ACC_BITS as i32), 0);
        assert_eq!(to_bits((1 << ACC_BITS) + 5), 5);
        // Positive overflow past 2^21 - 1 becomes the negative pattern.
        assert_eq!(to_bits(1 << 21), 1 << 21);
        assert_eq!(group_of(to_bits(1 << 21)) / HW_BINS, MSB_BINS - 1);
        // Max magnitude in range still maps to a valid group.
        assert!(group_of(to_bits((1 << 21) - 1)) < N_GROUPS);
        assert!(group_of(to_bits(-(1 << 21))) < N_GROUPS);
    }

    #[test]
    fn uniform_bin_edges() {
        // Exact edges of the uniform partitions: msb 0..=2 -> bin 0,
        // msb 3 -> bin 1; hw 0..=4 -> bin 0, hw 5 -> bin 1 (with 23
        // possible values in 10 resp. 5 bins).
        assert_eq!((2 * MSB_BINS) / (ACC_BITS + 1), 0);
        assert_eq!((3 * MSB_BINS) / (ACC_BITS + 1), 1);
        assert_eq!(group_of(0b10) / HW_BINS, 0); // msb 2
        assert_eq!(group_of(0b100) / HW_BINS, 1); // msb 3
        assert_eq!((4 * HW_BINS) / (ACC_BITS + 1), 0);
        assert_eq!((5 * HW_BINS) / (ACC_BITS + 1), 1);
        // msb 22 fixed, hw 4 vs 5 crosses the first HW edge.
        let base = 1u32 << 21;
        let hw4 = base | 0b111;
        let hw5 = base | 0b1111;
        assert_eq!(group_of(hw4) % HW_BINS, 0);
        assert_eq!(group_of(hw5) % HW_BINS, 1);
    }

    #[test]
    fn monotone_in_msb() {
        // Group id is non-decreasing in MSB position for fixed HW=1.
        let mut last = 0;
        for m in 0..22 {
            let g = group_of(1u32 << m);
            assert!(g >= last, "msb {m}");
            last = g;
        }
    }

    #[test]
    fn stability_ratio_separates() {
        // Synthetic: group g has cost g with tiny jitter -> huge ratio.
        let mut samples = Vec::new();
        for g in 0..N_GROUPS {
            for i in 0..5 {
                samples.push((g, g as f64 + i as f64 * 1e-6));
            }
        }
        assert!(stability_ratio(&samples) > 1e6);
        // All-identical costs -> ratio 0.
        let flat: Vec<(usize, f64)> = (0..N_GROUPS).flat_map(|g| [(g, 1.0), (g, 1.0)]).collect();
        assert_eq!(stability_ratio(&flat), 0.0);
    }

    #[test]
    fn ablation_groupings_valid() {
        let mut rng = crate::util::rng::Xoshiro256::new(7);
        for _ in 0..1000 {
            let v = (rng.next_u64() & ((1 << ACC_BITS) - 1)) as u32;
            for g in [Grouping::MsbHamming, Grouping::MsbOnly, Grouping::HammingOnly] {
                assert!(g.group(v) < N_GROUPS);
            }
        }
    }
}
