//! Naive baseline (§4.2 motivation, Table 4): pick the K weight values
//! with the lowest average MAC energy, ignoring representational
//! importance.  This is the strategy whose "catastrophic accuracy
//! degradation" motivates the co-optimized selection.

use crate::energy::WeightEnergyTable;
use crate::quant::{WeightSet, QMAX};

/// K lowest-energy codes.  Ties break toward smaller |code| so the result
/// is deterministic.  (0 usually wins anyway — it is the cheapest MAC.)
pub fn naive_lowest_energy(table: &WeightEnergyTable, k: usize) -> WeightSet {
    assert!(k >= 1);
    let mut codes: Vec<i32> = (-QMAX..=QMAX).collect();
    codes.sort_by(|&a, &b| {
        table
            .energy(a as i8)
            .partial_cmp(&table.energy(b as i8))
            .unwrap()
            .then(a.abs().cmp(&b.abs()))
            .then(a.cmp(&b))
    });
    WeightSet::new(codes.into_iter().take(k).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table() -> WeightEnergyTable {
        let mut e = [0.0f64; 256];
        for i in 0..256 {
            let code = (i as i32 - 128).unsigned_abs() as f64;
            e[i] = (1.0 + code) * 1e-15;
        }
        WeightEnergyTable {
            e_per_cycle: e,
            e_idle: 1e-16,
        }
    }

    #[test]
    fn picks_lowest_energy_codes() {
        let t = table();
        let set = naive_lowest_energy(&t, 5);
        assert_eq!(set.len(), 5);
        // With |code|-monotone energy, the 5 cheapest are {0, ±1, ±2}.
        for c in [0, 1, -1, 2, -2] {
            assert!(set.contains(c), "missing {c}");
        }
        assert!(!set.contains(64));
    }

    #[test]
    fn no_dynamic_range_in_naive_sets() {
        // The failure mode the paper highlights: the naive set has tiny
        // spread, destroying expressiveness.
        let t = table();
        let set = naive_lowest_energy(&t, 16);
        let max_abs = set.codes().iter().map(|c| c.abs()).max().unwrap();
        assert!(max_abs <= 8, "naive 16-set spread {max_abs} too large");
    }
}
