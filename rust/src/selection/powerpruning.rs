//! PowerPruning [15]-style baseline (Table 1's comparison row).
//!
//! Reimplemented per its published description: a **global** activation
//! model (MAC energy averaged over the whole network, not per layer), a
//! single 32-value weight set selected for low energy while keeping
//! enough dynamic range to train, and a **uniform** pruning ratio across
//! layers.  The two deliberate limitations relative to our method —
//! global statistics and layer-agnostic policy — are exactly what the
//! paper's ablations quantify.

use crate::energy::WeightEnergyTable;
use crate::quant::{WeightSet, QMAX};
use crate::selection::{CompressionState, LayerConfig};
use crate::util::threadpool::parallel_map;

/// Global low-energy set of size `k`, PowerPruning-style: greedily take
/// cheap codes but guarantee coverage of the dynamic range by reserving
/// logarithmically-spaced magnitude anchors (the published method selects
/// low-power weights subject to trainability; anchors are how we realize
/// that constraint deterministically).
pub fn powerpruning_set(table: &WeightEnergyTable, k: usize) -> WeightSet {
    powerpruning_set_with(table, k, 1)
}

/// [`powerpruning_set`] with an explicit worker count: the per-code
/// energy keys are materialized once through `parallel_map` (in code
/// order, so the ranking is thread-count independent) instead of being
/// re-read inside the comparator.
pub fn powerpruning_set_with(table: &WeightEnergyTable, k: usize, threads: usize) -> WeightSet {
    assert!(k >= 8, "PowerPruning uses sets of >= 8 values");
    let mut codes: Vec<i32> = vec![0];
    // Anchors: ±{127, 64, 32, 16} preserve range.
    for a in [127, -127, 64, -64, 32, -32, 16, -16] {
        if codes.len() < k {
            codes.push(a);
        }
    }
    // Fill the rest with the cheapest remaining codes.
    let rest: Vec<i32> = (-QMAX..=QMAX)
        .filter(|c| !codes.contains(c))
        .collect();
    let rest_ref = &rest;
    let keys: Vec<f64> = parallel_map(rest.len(), threads, |i| table.energy(rest_ref[i] as i8));
    let mut order: Vec<usize> = (0..rest.len()).collect();
    order.sort_by(|&ia, &ib| {
        let (a, b) = (rest[ia], rest[ib]);
        keys[ia]
            .partial_cmp(&keys[ib])
            .unwrap()
            .then(a.abs().cmp(&b.abs()))
            .then(a.cmp(&b))
    });
    codes.extend(
        order
            .into_iter()
            .map(|i| rest[i])
            .take(k - codes.len().min(k)),
    );
    codes.truncate(k);
    WeightSet::new(codes)
}

/// The full PowerPruning network policy: one global set, one uniform
/// pruning ratio for every conv layer.
pub fn powerpruning_state(
    n_conv: usize,
    table: &WeightEnergyTable,
    k: usize,
    uniform_ratio: f64,
) -> CompressionState {
    let set = powerpruning_set(table, k);
    CompressionState {
        layers: (0..n_conv)
            .map(|_| LayerConfig {
                prune_ratio: uniform_ratio,
                wset: Some(set.clone()),
            })
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table() -> WeightEnergyTable {
        let mut e = [0.0f64; 256];
        for i in 0..256 {
            let code = (i as i32 - 128).unsigned_abs() as f64;
            e[i] = (1.0 + code) * 1e-15;
        }
        WeightEnergyTable {
            e_per_cycle: e,
            e_idle: 1e-16,
        }
    }

    #[test]
    fn set_has_range_and_cheap_codes() {
        let s = powerpruning_set(&table(), 32);
        assert_eq!(s.len(), 32);
        assert!(s.contains(0) && s.contains(127) && s.contains(-127));
        // Majority of members are cheap (small |code|).
        let cheap = s.codes().iter().filter(|c| c.abs() <= 16).count();
        assert!(cheap >= 16, "only {cheap} cheap codes");
    }

    #[test]
    fn state_is_uniform() {
        let st = powerpruning_state(5, &table(), 32, 0.5);
        assert_eq!(st.layers.len(), 5);
        let first = st.layers[0].wset.clone().unwrap();
        for l in &st.layers {
            assert_eq!(l.prune_ratio, 0.5);
            assert_eq!(l.wset.as_ref().unwrap(), &first);
        }
    }
}
