//! Energy–accuracy co-optimized weight-set selection (paper §4.2) plus
//! the baselines it is evaluated against (naive top-K, PowerPruning).

pub mod greedy;
pub mod naive;
pub mod powerpruning;

pub use greedy::{
    greedy_backward_eliminate, projected_usage, safe_initial_set, set_energy, GreedyParams,
    GreedyTrace,
};
pub use naive::naive_lowest_energy;
pub use powerpruning::{powerpruning_set, powerpruning_set_with};

use crate::quant::WeightSet;

/// Per-conv-layer compression configuration.
#[derive(Clone, Debug, Default)]
pub struct LayerConfig {
    /// Magnitude-pruning ratio (0 = dense).
    pub prune_ratio: f64,
    /// Restricted weight set (None = full int8 range).
    pub wset: Option<WeightSet>,
}

/// Whole-network compression state (len = `n_conv`).
#[derive(Clone, Debug)]
pub struct CompressionState {
    pub layers: Vec<LayerConfig>,
}

impl CompressionState {
    pub fn dense(n_conv: usize) -> Self {
        Self {
            layers: vec![LayerConfig::default(); n_conv],
        }
    }
}

/// Accuracy oracle: the coordinator backs this with the AOT fine-tune /
/// eval graphs on PJRT; unit tests use synthetic functions.
pub trait AccuracyOracle {
    /// Validation accuracy (0..1) with `state` applied.
    fn accuracy(&mut self, state: &CompressionState) -> f64;

    /// Fine-tune the underlying weights for `steps` with `state` applied
    /// (QAT with projection), mutating the oracle's parameters.
    fn fine_tune(&mut self, state: &CompressionState, steps: usize);

    /// Number of accuracy evaluations performed (cost accounting).
    fn eval_count(&self) -> usize {
        0
    }

    /// Persist the oracle's mutable state (fine-tuned params) under
    /// `tag`, for resumable schedule searches.  Returns `false` when the
    /// oracle cannot snapshot (the default) — the search then restarts
    /// from scratch after an interruption instead of resuming.
    fn save_search_state(&mut self, _tag: &str) -> bool {
        false
    }

    /// Restore state saved by [`Self::save_search_state`].  Returns
    /// `false` when no snapshot exists under `tag`.
    fn load_search_state(&mut self, _tag: &str) -> bool {
        false
    }

    /// Delete the snapshot stored under `tag`, if any (cleanup for
    /// searches that no longer need an intermediate rung state).  The
    /// default is a no-op.
    fn drop_search_state(&mut self, _tag: &str) {}

    /// Stable identity of everything the oracle's accuracy numbers
    /// depend on *besides* the compression state: model, dataset seed,
    /// evaluation recipe, and the starting parameters.  The
    /// oracle-efficient schedule search folds this into its persistent
    /// accuracy-cache keys, so a cache warmed by one run is only
    /// consulted by runs that would reproduce the same numbers.  The
    /// default (empty string) is fine for single-context oracles such
    /// as unit-test fakes.
    fn search_context(&mut self) -> String {
        String::new()
    }

    /// Total fine-tune steps performed (cost accounting, mirroring
    /// [`Self::eval_count`]).
    fn ft_steps(&self) -> usize {
        0
    }
}
