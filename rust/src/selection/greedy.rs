//! Safe initial candidate set + greedy backward elimination (§4.2).
//!
//! Elimination score for removing code `w` from set `S`:
//!
//! ```text
//! S(w) = ΔE_ℓ(w) / (ΔAcc(w) + ε)
//! ```
//!
//! `ΔE_ℓ(w)` comes from the layer energy model with usage re-projected
//! onto `S \ {w}`.  `ΔAcc(w)` is estimated by a calibration-style proxy —
//! the L1 weight perturbation caused by remapping `w`'s occurrences to
//! the nearest survivor (`usage[w] · |w − proj(w)|`, normalized) — the
//! "calibration pass" variant the paper allows; optionally every accepted
//! removal is additionally validated against the real accuracy oracle
//! (`check_every_removal`), which is the paper's full procedure.

use super::{AccuracyOracle, CompressionState};
use crate::energy::LayerEnergy;
use crate::quant::{WeightSet, QMAX};
use crate::util::threadpool::parallel_map;

/// Parameters of the §4.2 procedure.
#[derive(Clone, Debug)]
pub struct GreedyParams {
    /// Initial candidate-set size (§4.2.1, "typically 32").
    pub k_init: usize,
    /// Target size (§4.2.2, e.g. 16).
    pub k_target: usize,
    /// ε in the removal score.
    pub eps: f64,
    /// Allowed accuracy drop δ below `acc0`.
    pub delta: f64,
    /// Baseline accuracy Acc₀.
    pub acc0: f64,
    /// Validate each accepted removal against the oracle (paper-exact;
    /// expensive) instead of only trusting the proxy.
    pub check_every_removal: bool,
    /// Worker threads for scoring removal candidates (0 = inherit the
    /// caller's default, which the coordinator sets to its pool width;
    /// scoring falls back to serial for small sets where fan-out costs
    /// more than it saves).  The chosen removal is independent of this
    /// value — scores are reduced in candidate order.
    pub threads: usize,
}

impl Default for GreedyParams {
    fn default() -> Self {
        Self {
            k_init: 32,
            k_target: 16,
            eps: 1e-3,
            delta: 0.03,
            acc0: 1.0,
            check_every_removal: false,
            threads: 0,
        }
    }
}

/// Usage histogram after projecting codes onto a set.
pub fn projected_usage(usage: &[u64; 256], set: &WeightSet) -> [u64; 256] {
    let mut out = [0u64; 256];
    for (i, &cnt) in usage.iter().enumerate() {
        if cnt == 0 {
            continue;
        }
        let code = i as i32 - 128;
        let p = set.project(code.clamp(-QMAX, QMAX));
        out[(p + 128) as usize] += cnt;
    }
    out
}

/// Layer energy when its codes are restricted to `set`.
pub fn set_energy(le: &LayerEnergy, usage: &[u64; 256], set: &WeightSet) -> f64 {
    le.energy_of_usage(&projected_usage(usage, set))
}

/// §4.2.1 — safe initial candidate set: rank codes by a joint score
/// favoring frequent use and low energy, keep the top `k_init`.
/// Code 0 is always included (pruning maps weights there), as are the
/// extreme codes ±127 (the scale anchors: without them the effective
/// dynamic range collapses).
pub fn safe_initial_set(usage: &[u64; 256], le: &LayerEnergy, k_init: usize) -> WeightSet {
    let e_min = le
        .table
        .e_per_cycle
        .iter()
        .cloned()
        .fold(f64::MAX, f64::min);
    let e_max = le.table.e_per_cycle.iter().cloned().fold(0.0f64, f64::max);
    let total: u64 = usage.iter().sum();
    let mut scored: Vec<(f64, i32)> = (-QMAX..=QMAX)
        .map(|code| {
            let u = usage[(code + 128) as usize] as f64 / total.max(1) as f64;
            let e = le.table.energy(code as i8);
            let e_norm = if e_max > e_min {
                (e - e_min) / (e_max - e_min)
            } else {
                0.0
            };
            // Frequent codes are valuable; expensive codes are penalized.
            let score = u - 0.3 * e_norm / 255.0;
            (score, code)
        })
        .collect();
    scored.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    let mut codes: Vec<i32> = vec![0, -QMAX, QMAX];
    for &(_, c) in &scored {
        if codes.len() >= k_init {
            break;
        }
        if !codes.contains(&c) {
            codes.push(c);
        }
    }
    WeightSet::new(codes)
}

/// Record of one elimination run (drives Table 4 / ablation reporting).
#[derive(Clone, Debug, Default)]
pub struct GreedyTrace {
    /// (removed_code, energy_after, proxy_acc_drop) per accepted removal.
    pub removals: Vec<(i32, f64, f64)>,
    /// Codes marked essential (removal rejected by the oracle).
    pub essential: Vec<i32>,
    pub oracle_evals: usize,
}

/// §4.2.2 — greedy backward elimination from `set0` down to `k_target`.
///
/// `usage` is the layer's weight-code usage *before* restriction (after
/// masking/quantization); `le` its energy model; `state`/`conv_idx`
/// locate the layer inside the network-level compression state used for
/// oracle checks.
#[allow(clippy::too_many_arguments)]
pub fn greedy_backward_eliminate(
    set0: WeightSet,
    usage: &[u64; 256],
    le: &LayerEnergy,
    oracle: &mut dyn AccuracyOracle,
    state: &mut CompressionState,
    conv_idx: usize,
    p: &GreedyParams,
) -> (WeightSet, GreedyTrace) {
    let mut set = set0;
    let mut trace = GreedyTrace::default();
    let total_usage: f64 = usage.iter().sum::<u64>().max(1) as f64;
    let mut essential: Vec<i32> = Vec::new();

    while set.len() > p.k_target {
        let e_cur = set_energy(le, usage, &set);
        // Score every removable code by S(w) = ΔE / (ΔAccProxy + ε).
        // Each candidate is independent, so the scoring fans out over
        // the thread pool; the winner is then reduced in candidate
        // order, which keeps the result bit-identical to the serial
        // sweep (first strict maximum wins either way).
        let score_one = |w: i32| -> Option<(f64, i32, f64, f64)> {
            if w == 0 || essential.contains(&w) {
                return None; // 0 anchors pruning; essentials are frozen
            }
            let smaller = set.without(w);
            let e_new = set_energy(le, usage, &smaller);
            let de = (e_cur - e_new).max(0.0);
            // Calibration proxy for ΔAcc: normalized L1 perturbation of
            // remapping w's occurrences to the nearest survivor.
            let remap = smaller.project(w);
            let perturb = usage[(w + 128) as usize] as f64 * (w - remap).abs() as f64;
            let proxy = perturb / (total_usage * QMAX as f64);
            let score = de / (proxy + p.eps * 1e-15); // ε scaled to J
            Some((score, w, e_new, proxy))
        };
        let codes = set.codes();
        let scored: Vec<Option<(f64, i32, f64, f64)>> = if p.threads > 1 && codes.len() >= 24 {
            parallel_map(codes.len(), p.threads, |i| score_one(codes[i]))
        } else {
            codes.iter().map(|&w| score_one(w)).collect()
        };
        let mut best: Option<(f64, i32, f64, f64)> = None; // (score, code, e_new, proxy)
        for cand in scored.into_iter().flatten() {
            if best.map(|(s, ..)| cand.0 > s).unwrap_or(true) {
                best = Some(cand);
            }
        }
        let Some((_, w_star, e_new, proxy)) = best else {
            break; // nothing removable
        };
        let candidate = set.without(w_star);
        if p.check_every_removal {
            state.layers[conv_idx].wset = Some(candidate.clone());
            let acc = oracle.accuracy(state);
            trace.oracle_evals += 1;
            if acc < p.acc0 - p.delta {
                essential.push(w_star);
                trace.essential.push(w_star);
                // Restore state and try the next-best candidate.
                state.layers[conv_idx].wset = Some(set.clone());
                continue;
            }
        }
        set = candidate;
        trace.removals.push((w_star, e_new, proxy));
    }
    state.layers[conv_idx].wset = Some(set.clone());
    (set, trace)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::WeightEnergyTable;

    fn le_fixture() -> LayerEnergy {
        let mut e = [0.0f64; 256];
        for i in 0..256 {
            let code = (i as i32 - 128).unsigned_abs() as f64;
            // Energy grows with |code| (the Fig. 1 trend).
            e[i] = (1.0 + code) * 1e-15;
        }
        LayerEnergy {
            conv_idx: 0,
            m: 64,
            k: 64,
            n: 64,
            table: WeightEnergyTable {
                e_per_cycle: e,
                e_idle: 0.5e-15,
            },
        }
    }

    fn usage_fixture() -> [u64; 256] {
        // Gaussian-ish usage centered at 0 with tails.
        let mut u = [0u64; 256];
        for code in -127i32..=127 {
            let x = code as f64 / 30.0;
            u[(code + 128) as usize] = (1000.0 * (-x * x).exp()) as u64 + 1;
        }
        u
    }

    struct NullOracle;
    impl AccuracyOracle for NullOracle {
        fn accuracy(&mut self, _: &CompressionState) -> f64 {
            1.0
        }
        fn fine_tune(&mut self, _: &CompressionState, _: usize) {}
    }

    #[test]
    fn initial_set_contains_anchors_and_frequent() {
        let le = le_fixture();
        let usage = usage_fixture();
        let set = safe_initial_set(&usage, &le, 32);
        assert_eq!(set.len(), 32);
        assert!(set.contains(0));
        assert!(set.contains(QMAX) && set.contains(-QMAX));
        // The most frequent nonzero codes (near 0) should be in.
        assert!(set.contains(1) || set.contains(-1));
    }

    #[test]
    fn elimination_reaches_target_and_reduces_energy() {
        let le = le_fixture();
        let usage = usage_fixture();
        let set0 = safe_initial_set(&usage, &le, 32);
        let e0 = set_energy(&le, &usage, &set0);
        let mut state = CompressionState::dense(1);
        let mut oracle = NullOracle;
        let p = GreedyParams::default();
        let (set, trace) = greedy_backward_eliminate(
            set0, &usage, &le, &mut oracle, &mut state, 0, &p,
        );
        assert_eq!(set.len(), 16);
        assert_eq!(trace.removals.len(), 16);
        let e1 = set_energy(&le, &usage, &set);
        assert!(e1 <= e0, "energy must not increase: {e0} -> {e1}");
        assert!(set.contains(0));
    }

    #[test]
    fn oracle_rejection_marks_essential() {
        let le = le_fixture();
        let usage = usage_fixture();
        let set0 = WeightSet::new(vec![-127, -64, -32, 0, 32, 64, 127]);
        struct Fussy {
            evals: usize,
        }
        impl AccuracyOracle for Fussy {
            fn accuracy(&mut self, state: &CompressionState) -> f64 {
                self.evals += 1;
                // Reject any set that drops 64 or -64.
                let s = state.layers[0].wset.as_ref().unwrap();
                if s.contains(64) && s.contains(-64) {
                    1.0
                } else {
                    0.0
                }
            }
            fn fine_tune(&mut self, _: &CompressionState, _: usize) {}
            fn eval_count(&self) -> usize {
                self.evals
            }
        }
        let mut oracle = Fussy { evals: 0 };
        let mut state = CompressionState::dense(1);
        let p = GreedyParams {
            k_target: 5,
            check_every_removal: true,
            delta: 0.01,
            acc0: 1.0,
            ..Default::default()
        };
        let (set, trace) = greedy_backward_eliminate(
            set0, &usage, &le, &mut oracle, &mut state, 0, &p,
        );
        assert!(set.contains(64) && set.contains(-64));
        assert_eq!(set.len(), 5);
        assert!(!trace.essential.is_empty());
    }

    #[test]
    fn projected_usage_conserves_mass() {
        let usage = usage_fixture();
        let set = WeightSet::new(vec![-100, -20, 0, 20, 100]);
        let pu = projected_usage(&usage, &set);
        assert_eq!(
            usage.iter().sum::<u64>(),
            pu.iter().sum::<u64>(),
            "projection must conserve weight count"
        );
        for (i, &c) in pu.iter().enumerate() {
            if c > 0 {
                assert!(set.contains(i as i32 - 128));
            }
        }
    }
}
