//! Training/eval runtime: a [`ModelRuntime`] facade over pluggable
//! [`Backend`]s.
//!
//! Two backends implement the same four drivers (`train_steps`,
//! `evaluate`, `logits`, `calibrate`):
//!
//! * [`AotBackend`] — loads the AOT HLO-text artifacts produced by
//!   `python/compile/aot.py` and executes them through PJRT (Python
//!   never runs here; interchange is HLO *text* — xla_extension 0.5.1
//!   rejects jax ≥ 0.5 serialized protos, see DESIGN.md / aot.py).
//! * [`native::NativeBackend`] — the pure-Rust mirror: reverse-mode
//!   QAT training ([`crate::model::GradEngine`]) and the int8 inference
//!   engine ([`crate::model::ParallelEngine`]), data-parallel across
//!   the batch and bit-identical at any thread count.  Needs no
//!   artifacts, which makes the full train → profile → compress flow
//!   run offline — and turns the accuracy oracle (the dominant cost of
//!   the §4.3 schedule) into a multi-threaded hot path.
//!
//! [`ModelRuntime::auto`] picks AOT when artifacts exist and the PJRT
//! client comes up, native otherwise; [`BackendChoice`] forces either.

pub mod native;

use crate::data::{self, Split};
use crate::model::{ModelSpec, Params};
use crate::quant::{magnitude_mask, KSET, SET_SENTINEL};
use crate::selection::CompressionState;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

/// Learning-rate schedule for the training driver.
#[derive(Clone, Copy, Debug)]
pub struct LrSchedule {
    pub base: f32,
    /// Step fraction after which lr drops by 5×.
    pub decay_at: f32,
}

impl Default for LrSchedule {
    fn default() -> Self {
        Self {
            base: 0.01,
            decay_at: 0.75,
        }
    }
}

/// Checkpoint/resume policy for [`ModelRuntime::train_steps_resumable`].
#[derive(Clone, Debug)]
pub struct ResumeOpts {
    /// Save a checkpoint every `every` executed steps.  `0` disables
    /// checkpointing, resume, and rollback entirely — the historical
    /// [`ModelRuntime::train_steps`] behavior, bit for bit.
    pub every: usize,
    /// Checkpoint tag; the file is `ckpt.<tag>.bin` in the runtime dir.
    pub tag: String,
    /// Max divergence rollbacks before the run gives up and errors.
    pub max_rollbacks: u32,
    /// Learning-rate multiplier applied on each divergence rollback.
    pub backoff: f32,
    /// Execute at most this many steps in THIS invocation, then return
    /// with `completed = false` **without saving** — modeling a hard
    /// kill: resume recovers from the last periodic checkpoint and
    /// recomputes the tail, which is what makes kill-and-resume
    /// bit-identical to an uninterrupted run.
    pub max_steps_this_run: Option<usize>,
}

impl ResumeOpts {
    /// Checkpoint every `every` steps under `tag`, with the default
    /// divergence policy (3 rollbacks, lr × 0.5 per rollback).
    pub fn every(every: usize, tag: &str) -> Self {
        Self {
            every,
            tag: tag.to_string(),
            max_rollbacks: 3,
            backoff: 0.5,
            max_steps_this_run: None,
        }
    }

    fn disabled() -> Self {
        Self {
            every: 0,
            tag: String::new(),
            max_rollbacks: 0,
            backoff: 1.0,
            max_steps_this_run: None,
        }
    }
}

/// Outcome of a [`ModelRuntime::train_steps_resumable`] invocation.
#[derive(Clone, Debug)]
pub struct TrainProgress {
    /// Whether the full step schedule has completed.
    pub completed: bool,
    /// Mean loss over the final (up to) 10 steps executed this
    /// invocation.
    pub loss: f32,
    /// Steps executed in this invocation (resumed steps not counted).
    pub steps_run: usize,
    /// Schedule position reached (`== steps` when completed).
    pub at_step: usize,
    /// Divergence rollbacks performed so far across the whole run.
    pub rollbacks: u32,
    /// True when a checkpoint was found and adopted at entry.
    pub resumed: bool,
}

/// State adopted from a checkpoint (the f32 payload goes straight into
/// the runtime; this carries the loop-control fields).
struct CkptMeta {
    steps_into_run: usize,
    lr_scale: f32,
    rollbacks: u32,
}

/// Which backend a runtime should use.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum BackendChoice {
    /// AOT-PJRT when artifacts exist and the client comes up, else
    /// native.
    #[default]
    Auto,
    /// Require the AOT artifacts (error when absent).
    Aot,
    /// Pure-Rust backend, no artifacts touched.
    Native,
}

impl BackendChoice {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "auto" => Ok(Self::Auto),
            "aot" | "pjrt" => Ok(Self::Aot),
            "native" => Ok(Self::Native),
            other => bail!("unknown backend `{other}` (auto | aot | native)"),
        }
    }
}

/// The mutable runtime state a [`Backend`] operates on — the facade
/// owns it, so backends stay swappable without moving parameters.
pub struct RtCtx<'a> {
    pub spec: &'a ModelSpec,
    pub params: &'a mut Vec<Vec<f32>>,
    pub mom: &'a mut Vec<Vec<f32>>,
    pub act_scales: &'a mut Vec<f32>,
    pub data_seed: u64,
    pub steps_done: &'a mut u64,
    pub threads: usize,
}

/// A training/evaluation engine.  All four drivers share the exact data
/// recipe (seed, split, batch offsets), so backends are interchangeable
/// mid-pipeline.
pub trait Backend {
    fn name(&self) -> &'static str;

    /// Run ONE SGD+momentum step at `step_lr`: fetch the train batch at
    /// the `steps_done · batch_train` cursor, update params/momentum,
    /// advance the cursor, return the batch loss.  The surrounding loop
    /// (lr decay schedule, divergence bail-out, loss window) lives in
    /// [`ModelRuntime::train_steps`], so every backend shares one
    /// training recipe by construction.
    fn train_step(
        &mut self,
        ctx: RtCtx<'_>,
        state: &CompressionState,
        quant_on: bool,
        step_lr: f32,
    ) -> Result<f32>;

    /// Fraction correct over `n_batches` of `split` (batch =
    /// `spec.batch_eval`).
    fn evaluate(
        &mut self,
        ctx: RtCtx<'_>,
        state: &CompressionState,
        quant_on: bool,
        split: Split,
        n_batches: usize,
    ) -> Result<f64>;

    /// Logits for a raw `spec.batch_logits`-sized input batch.
    fn logits(
        &mut self,
        ctx: RtCtx<'_>,
        state: &CompressionState,
        quant_on: bool,
        x: &[f32],
    ) -> Result<Vec<f32>>;

    /// Calibrate activation scales over `n_batches` of train data;
    /// stores them in the ctx and returns them.
    fn calibrate(&mut self, ctx: RtCtx<'_>, n_batches: usize) -> Result<Vec<f32>>;
}

// -- shared input lowering ---------------------------------------------------

fn lit_f32(data: &[f32], dims: &[i64]) -> Result<Literal> {
    Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| anyhow!("reshape {dims:?}: {e:?}"))
}

fn lit_scalar(v: f32) -> Literal {
    Literal::scalar(v)
}

/// Per-conv optional magnitude masks (`None` = dense) from the
/// *current* params under `state`, indexed by `conv_idx` — the single
/// mask recipe shared by the AOT literal path ([`masks_for`]) and the
/// native backend's `QuantConfig`, so the backends cannot drift apart.
/// (Pruned weights receive no gradient, so per-step recomputation is
/// stable across fine-tune steps.)
pub fn mask_options(
    spec: &ModelSpec,
    params: &[Vec<f32>],
    state: &CompressionState,
) -> Vec<Option<Vec<f32>>> {
    let mut masks = vec![None; spec.n_conv];
    for c in spec.convs() {
        let ratio = state.layers[c.conv_idx].prune_ratio;
        if ratio > 0.0 {
            masks[c.conv_idx] = Some(magnitude_mask(&params[c.w], ratio));
        }
    }
    masks
}

/// [`mask_options`] densified for the AOT graphs' literal inputs
/// (dense layers become explicit all-ones tensors), in `conv_idx`
/// order.
pub fn masks_for(
    spec: &ModelSpec,
    params: &[Vec<f32>],
    state: &CompressionState,
) -> Vec<Vec<f32>> {
    mask_options(spec, params, state)
        .into_iter()
        .zip(spec.convs())
        .map(|(m, c)| m.unwrap_or_else(|| vec![1.0f32; params[c.w].len()]))
        .collect()
}

/// The PJRT-free calibration recipe shared by
/// [`ModelRuntime::calibrate_native`] and the native backend: the same
/// data recipe as the AOT `calib` graph (train split,
/// `batch_calib`-sized batches from offset 0) through the compiled
/// float engine, one forward scratch per worker reused across the
/// whole batch loop.  Returns the per-quant-point scales.
pub fn calibrate_scales(
    spec: &ModelSpec,
    params: &[Vec<f32>],
    data_seed: u64,
    n_batches: usize,
    threads: usize,
) -> Vec<f32> {
    let bs = spec.batch_calib;
    let qc = crate::model::QuantConfig::float(spec);
    let eng = crate::model::ParallelEngine::new(spec, params, &qc, threads);
    let batches: Vec<Vec<f32>> = (0..n_batches)
        .map(|b| {
            data::batch(
                data_seed,
                Split::Train,
                (b * bs) as u64,
                bs,
                spec.n_classes as u64,
            )
            .0
        })
        .collect();
    let refs: Vec<&[f32]> = batches.iter().map(Vec::as_slice).collect();
    eng.calibrate(&refs, bs)
}

fn wset_tables(spec: &ModelSpec, state: &CompressionState) -> (Vec<[f32; KSET]>, Vec<f32>) {
    let mut tables = Vec::with_capacity(spec.n_conv);
    let mut on = Vec::with_capacity(spec.n_conv);
    for l in &state.layers {
        match &l.wset {
            Some(s) => {
                tables.push(s.padded_table());
                on.push(1.0f32);
            }
            None => {
                tables.push([SET_SENTINEL; KSET]);
                on.push(0.0f32);
            }
        }
    }
    (tables, on)
}

// -- the AOT-PJRT backend ----------------------------------------------------

/// Executes the AOT-compiled HLO graphs through PJRT.  Executables
/// compile lazily on first use.
pub struct AotBackend {
    client: PjRtClient,
    exes: HashMap<String, PjRtLoadedExecutable>,
    dir: PathBuf,
}

impl AotBackend {
    /// Connect the PJRT CPU client for the artifacts in `dir` (the
    /// per-model directory holding `manifest.json` + `*.hlo.txt`).
    pub fn new(dir: PathBuf) -> Result<Self> {
        let client = PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Self {
            client,
            exes: HashMap::new(),
            dir,
        })
    }

    fn exe(&mut self, spec: &ModelSpec, entry: &str) -> Result<&PjRtLoadedExecutable> {
        if !self.exes.contains_key(entry) {
            let meta = spec
                .entries
                .iter()
                .find(|(n, _)| n == entry)
                .map(|(_, m)| m.clone())
                .ok_or_else(|| anyhow!("no entry `{entry}` in manifest"))?;
            let path = self.dir.join(&meta.file);
            let proto = HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
            let comp = XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {entry}: {e:?}"))?;
            crate::info!("compiled {}/{} ({} inputs)", spec.name, entry, meta.n_inputs);
            self.exes.insert(entry.to_string(), exe);
        }
        Ok(self.exes.get(entry).unwrap())
    }

    /// Common input prefix for eval/logits: params, masks, wsets,
    /// wset_on, act_scales, quant_on.
    fn common_inputs(ctx: &RtCtx<'_>, state: &CompressionState, quant_on: bool) -> Result<Vec<Literal>> {
        let spec = ctx.spec;
        let mut ins = Vec::new();
        for (t, p) in ctx.params.iter().zip(&spec.params) {
            let dims: Vec<i64> = p.shape.iter().map(|&d| d as i64).collect();
            ins.push(lit_f32(t, &dims)?);
        }
        let masks = masks_for(spec, ctx.params.as_slice(), state);
        for (m, c) in masks.iter().zip(spec.convs()) {
            let p = &spec.params[c.w];
            let dims: Vec<i64> = p.shape.iter().map(|&d| d as i64).collect();
            ins.push(lit_f32(m, &dims)?);
        }
        let (tables, on) = wset_tables(spec, state);
        for t in &tables {
            ins.push(lit_f32(t, &[KSET as i64])?);
        }
        ins.push(lit_f32(&on, &[spec.n_conv as i64])?);
        ins.push(lit_f32(ctx.act_scales.as_slice(), &[spec.n_q as i64])?);
        ins.push(lit_scalar(if quant_on { 1.0 } else { 0.0 }));
        Ok(ins)
    }

    fn batch_literals(
        ctx: &RtCtx<'_>,
        split: Split,
        start: u64,
        size: usize,
    ) -> Result<(Literal, Literal)> {
        let (xs, ys) = data::batch(ctx.data_seed, split, start, size, ctx.spec.n_classes as u64);
        let x = lit_f32(&xs, &[size as i64, 32, 32, 3])?;
        let y = Literal::vec1(&ys);
        Ok((x, y))
    }
}

impl Backend for AotBackend {
    fn name(&self) -> &'static str {
        "aot-pjrt"
    }

    fn train_step(
        &mut self,
        ctx: RtCtx<'_>,
        state: &CompressionState,
        quant_on: bool,
        step_lr: f32,
    ) -> Result<f32> {
        let spec = ctx.spec;
        let bs = spec.batch_train;
        let n_p = spec.params.len();
        let cursor = *ctx.steps_done * bs as u64;
        let (x, y) = Self::batch_literals(&ctx, Split::Train, cursor, bs)?;

        let mut ins: Vec<Literal> = Vec::new();
        for (t, p) in ctx.params.iter().zip(&spec.params) {
            let dims: Vec<i64> = p.shape.iter().map(|&d| d as i64).collect();
            ins.push(lit_f32(t, &dims)?);
        }
        for (t, p) in ctx.mom.iter().zip(&spec.params) {
            let dims: Vec<i64> = p.shape.iter().map(|&d| d as i64).collect();
            ins.push(lit_f32(t, &dims)?);
        }
        let masks = masks_for(spec, ctx.params.as_slice(), state);
        for (m, c) in masks.iter().zip(spec.convs()) {
            let p = &spec.params[c.w];
            let dims: Vec<i64> = p.shape.iter().map(|&d| d as i64).collect();
            ins.push(lit_f32(m, &dims)?);
        }
        let (tables, on) = wset_tables(spec, state);
        for t in &tables {
            ins.push(lit_f32(t, &[KSET as i64])?);
        }
        ins.push(lit_f32(&on, &[spec.n_conv as i64])?);
        ins.push(lit_f32(ctx.act_scales.as_slice(), &[spec.n_q as i64])?);
        ins.push(lit_scalar(if quant_on { 1.0 } else { 0.0 }));
        ins.push(lit_scalar(step_lr));
        ins.push(x);
        ins.push(y);

        let exe = self.exe(spec, "train")?;
        let result = exe
            .execute::<Literal>(&ins)
            .map_err(|e| anyhow!("train exec: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("train sync: {e:?}"))?;
        let outs = result.to_tuple().map_err(|e| anyhow!("train tuple: {e:?}"))?;
        if outs.len() != 2 * n_p + 1 {
            bail!("train output arity {} != {}", outs.len(), 2 * n_p + 1);
        }
        for (i, o) in outs.iter().enumerate().take(n_p) {
            ctx.params[i] = o.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        }
        for i in 0..n_p {
            ctx.mom[i] = outs[n_p + i]
                .to_vec::<f32>()
                .map_err(|e| anyhow!("{e:?}"))?;
        }
        let loss: f32 = outs[2 * n_p]
            .get_first_element()
            .map_err(|e| anyhow!("{e:?}"))?;
        *ctx.steps_done += 1;
        Ok(loss)
    }

    fn evaluate(
        &mut self,
        ctx: RtCtx<'_>,
        state: &CompressionState,
        quant_on: bool,
        split: Split,
        n_batches: usize,
    ) -> Result<f64> {
        let bs = ctx.spec.batch_eval;
        let mut correct = 0.0f64;
        for b in 0..n_batches {
            let mut ins = Self::common_inputs(&ctx, state, quant_on)?;
            let (x, y) = Self::batch_literals(&ctx, split, (b * bs) as u64, bs)?;
            ins.push(x);
            ins.push(y);
            let exe = self.exe(ctx.spec, "eval")?;
            let result = exe
                .execute::<Literal>(&ins)
                .map_err(|e| anyhow!("eval exec: {e:?}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("eval sync: {e:?}"))?;
            let (nc, _loss) = result
                .to_tuple2()
                .map_err(|e| anyhow!("eval tuple: {e:?}"))?;
            let nc: f32 = nc.get_first_element().map_err(|e| anyhow!("{e:?}"))?;
            correct += nc as f64;
        }
        Ok(correct / (n_batches * bs) as f64)
    }

    fn logits(
        &mut self,
        ctx: RtCtx<'_>,
        state: &CompressionState,
        quant_on: bool,
        x: &[f32],
    ) -> Result<Vec<f32>> {
        let bs = ctx.spec.batch_logits;
        assert_eq!(x.len(), bs * 32 * 32 * 3);
        let mut ins = Self::common_inputs(&ctx, state, quant_on)?;
        ins.push(lit_f32(x, &[bs as i64, 32, 32, 3])?);
        let exe = self.exe(ctx.spec, "logits")?;
        let result = exe
            .execute::<Literal>(&ins)
            .map_err(|e| anyhow!("logits exec: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("logits sync: {e:?}"))?;
        let out = result
            .to_tuple1()
            .map_err(|e| anyhow!("logits tuple: {e:?}"))?;
        out.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))
    }

    fn calibrate(&mut self, ctx: RtCtx<'_>, n_batches: usize) -> Result<Vec<f32>> {
        let spec = ctx.spec;
        let bs = spec.batch_calib;
        let mut maxes = vec![0.0f32; spec.n_q];
        for b in 0..n_batches {
            let mut ins: Vec<Literal> = Vec::new();
            for (t, p) in ctx.params.iter().zip(&spec.params) {
                let dims: Vec<i64> = p.shape.iter().map(|&d| d as i64).collect();
                ins.push(lit_f32(t, &dims)?);
            }
            let (x, _y) = Self::batch_literals(&ctx, Split::Train, (b * bs) as u64, bs)?;
            ins.push(x);
            let exe = self.exe(spec, "calib")?;
            let result = exe
                .execute::<Literal>(&ins)
                .map_err(|e| anyhow!("calib exec: {e:?}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("calib sync: {e:?}"))?;
            let (out, _logit_mean) = result
                .to_tuple2()
                .map_err(|e| anyhow!("calib tuple: {e:?}"))?;
            let v = out.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
            for (m, x) in maxes.iter_mut().zip(&v) {
                *m = m.max(*x);
            }
        }
        *ctx.act_scales = maxes
            .iter()
            .map(|&m| (m / crate::quant::QMAX as f32).max(1e-9))
            .collect();
        Ok(ctx.act_scales.clone())
    }
}

// -- the facade --------------------------------------------------------------

/// A loaded model: spec + resident parameters + the backend executing
/// the training/eval drivers.
pub struct ModelRuntime {
    pub spec: ModelSpec,
    dir: PathBuf,
    /// Float shadow parameters (updated by train steps).
    pub params: Vec<Vec<f32>>,
    /// Momentum buffers.
    mom: Vec<Vec<f32>>,
    /// Per-quant-point activation scales (0 until calibrated).
    pub act_scales: Vec<f32>,
    /// Dataset seed (shared with data generation everywhere); plumbed
    /// from `PipelineParams::data_seed` / `--data-seed`.
    pub data_seed: u64,
    /// Executed-step counter (drives the train-data cursor).
    pub steps_done: u64,
    /// Worker threads for the native engines.
    pub threads: usize,
    backend: Box<dyn Backend>,
}

impl ModelRuntime {
    /// Default dataset seed (the historical hard-coded value, now a
    /// named constant overridable via `PipelineParams::data_seed`).
    pub const DEFAULT_DATA_SEED: u64 = 7;

    fn assemble(
        spec: ModelSpec,
        params: Vec<Vec<f32>>,
        dir: PathBuf,
        backend: Box<dyn Backend>,
    ) -> Self {
        let mom = spec.params.iter().map(|p| vec![0.0f32; p.numel()]).collect();
        let n_q = spec.n_q;
        Self {
            spec,
            dir,
            params,
            mom,
            act_scales: vec![0.0; n_q],
            data_seed: Self::DEFAULT_DATA_SEED,
            steps_done: 0,
            threads: crate::util::threadpool::default_threads(),
            backend,
        }
    }

    /// Load manifest + initial params and connect the PJRT CPU client
    /// (the AOT backend).  Executables compile lazily on first use.
    pub fn load(artifacts_dir: &Path, model: &str) -> Result<Self> {
        let dir = artifacts_dir.join(model);
        let spec = ModelSpec::from_manifest_file(&dir.join("manifest.json"))?;
        let params = Params::load(&spec, &dir.join("params.bin"))?;
        let backend = Box::new(AotBackend::new(dir.clone())?);
        Ok(Self::assemble(spec, params.tensors, dir, backend))
    }

    /// Pure-Rust runtime, no PJRT: the manifest + `params.bin` are used
    /// when present (so native runs continue AOT state); otherwise the
    /// built-in spec ([`ModelSpec::builtin`]) with fresh training init.
    pub fn native(artifacts_dir: &Path, model: &str) -> Result<Self> {
        let dir = artifacts_dir.join(model);
        let manifest = dir.join("manifest.json");
        let (spec, params) = if manifest.exists() {
            let spec = ModelSpec::from_manifest_file(&manifest)?;
            let pbin = dir.join("params.bin");
            let params = if pbin.exists() {
                Params::load(&spec, &pbin)?.tensors
            } else {
                Params::init_train(&spec, spec.seed).tensors
            };
            (spec, params)
        } else {
            let spec = ModelSpec::builtin(model)
                .with_context(|| format!("no artifacts at {} and no built-in spec", dir.display()))?;
            let params = Params::init_train(&spec, spec.seed).tensors;
            (spec, params)
        };
        Ok(Self::assemble(
            spec,
            params,
            dir,
            Box::new(native::NativeBackend::default()),
        ))
    }

    /// Construct a native runtime from an explicit spec (tests, benches
    /// and synthetic workloads).  `dir` is only used for checkpoints.
    pub fn from_spec_native(spec: ModelSpec, params: Vec<Vec<f32>>, dir: PathBuf) -> Self {
        assert_eq!(params.len(), spec.params.len());
        Self::assemble(spec, params, dir, Box::new(native::NativeBackend::default()))
    }

    /// Assemble a runtime around an explicit backend (scripted backends
    /// in tests; future backends plug in without a facade fork).
    pub fn with_backend(
        spec: ModelSpec,
        params: Vec<Vec<f32>>,
        dir: PathBuf,
        backend: Box<dyn Backend>,
    ) -> Self {
        assert_eq!(params.len(), spec.params.len());
        Self::assemble(spec, params, dir, backend)
    }

    /// Directory holding this runtime's artifacts and checkpoints.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Backend selection: AOT when artifacts exist and PJRT comes up
    /// (unless forced), native otherwise.
    pub fn auto(artifacts_dir: &Path, model: &str, choice: BackendChoice) -> Result<Self> {
        match choice {
            BackendChoice::Aot => Self::load(artifacts_dir, model),
            BackendChoice::Native => Self::native(artifacts_dir, model),
            BackendChoice::Auto => {
                let manifest = artifacts_dir.join(model).join("manifest.json");
                if manifest.exists() {
                    match Self::load(artifacts_dir, model) {
                        Ok(rt) => return Ok(rt),
                        Err(e) => {
                            crate::info!(
                                "{model}: AOT backend unavailable ({e}); falling back to native"
                            );
                        }
                    }
                }
                Self::native(artifacts_dir, model)
            }
        }
    }

    /// Name of the active backend (`aot-pjrt` | `native`).
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    fn ctx(&mut self) -> (&mut dyn Backend, RtCtx<'_>) {
        (
            self.backend.as_mut(),
            RtCtx {
                spec: &self.spec,
                params: &mut self.params,
                mom: &mut self.mom,
                act_scales: &mut self.act_scales,
                data_seed: self.data_seed,
                steps_done: &mut self.steps_done,
                threads: self.threads,
            },
        )
    }

    /// Materialize per-conv masks from the current params under `state`.
    pub fn masks_for(&self, state: &CompressionState) -> Vec<Vec<f32>> {
        masks_for(&self.spec, &self.params, state)
    }

    // -- drivers (dispatch to the backend) ----------------------------------

    /// Run `steps` SGD+momentum steps.  Returns the mean loss of the
    /// final 10 steps.  The lr decay schedule, divergence bail-out and
    /// loss window live here — backends only provide the per-step
    /// compute — so the training recipe is identical across backends by
    /// construction.
    pub fn train_steps(
        &mut self,
        state: &CompressionState,
        quant_on: bool,
        lr: LrSchedule,
        steps: usize,
    ) -> Result<f32> {
        let p = self.train_steps_resumable(state, quant_on, lr, steps, &ResumeOpts::disabled())?;
        Ok(p.loss)
    }

    /// [`Self::train_steps`] with checkpoint/resume and bounded
    /// divergence rollback:
    ///
    /// * every `opts.every` steps the full mutable training state
    ///   (params, momentum, activation scales, data-cursor step counter)
    ///   is checkpointed atomically to `ckpt.<tag>.bin`;
    /// * at entry, an existing checkpoint for the same (model, total
    ///   steps, data seed) is adopted, so a killed run resumes where the
    ///   last checkpoint left it — and, because a step is a pure
    ///   function of (params, momentum, scales, data cursor), the
    ///   resumed run's final params are **bit-identical** to an
    ///   uninterrupted run at any thread count (there is no live RNG in
    ///   the train loop: data sampling is random-access from
    ///   `data_seed` + cursor, and masks are recomputed from the float
    ///   shadow weights each step — the checkpoint *is* the full state);
    /// * a non-finite loss rolls back to the last checkpoint with the
    ///   learning rate scaled by `opts.backoff`, at most
    ///   `opts.max_rollbacks` times, instead of bailing immediately.
    ///
    /// The checkpoint file is deleted on completion.  A corrupt
    /// checkpoint is a hard error naming the file and reason — never
    /// silently ignored.  With `opts.every == 0` this is exactly the
    /// historical `train_steps` loop.
    pub fn train_steps_resumable(
        &mut self,
        state: &CompressionState,
        quant_on: bool,
        lr: LrSchedule,
        steps: usize,
        opts: &ResumeOpts,
    ) -> Result<TrainProgress> {
        let mut s = 0usize;
        let mut lr_scale = 1.0f32;
        let mut rollbacks = 0u32;
        let mut resumed = false;
        if opts.every > 0 {
            if let Some(meta) = self.try_adopt_checkpoint(&opts.tag, steps)? {
                s = meta.steps_into_run;
                lr_scale = meta.lr_scale;
                rollbacks = meta.rollbacks;
                resumed = true;
                crate::info!(
                    "{}: resumed checkpoint `{}` at step {s}/{steps} ({rollbacks} rollbacks so far)",
                    self.spec.name,
                    opts.tag
                );
            } else {
                // Initial checkpoint: a rollback target exists even for
                // divergences before the first periodic save.
                self.save_checkpoint(&opts.tag, steps, 0, lr_scale, rollbacks)?;
            }
        }
        let mut recent: Vec<f32> = Vec::new();
        let mut steps_run = 0usize;
        while s < steps {
            if let Some(limit) = opts.max_steps_this_run {
                if steps_run >= limit {
                    // Hard-kill model: return WITHOUT saving; resume
                    // recomputes from the last periodic checkpoint.
                    return Ok(TrainProgress {
                        completed: false,
                        loss: recent.iter().sum::<f32>() / recent.len().max(1) as f32,
                        steps_run,
                        at_step: s,
                        rollbacks,
                        resumed,
                    });
                }
            }
            let base = if (s as f32) < lr.decay_at * steps as f32 {
                lr.base
            } else {
                lr.base / 5.0
            };
            // lr_scale is exactly 1.0 until a rollback fires, and
            // `x * 1.0` is bit-exact, so the plain train_steps path is
            // unchanged bit for bit.
            let step_lr = base * lr_scale;
            let (backend, ctx) = self.ctx();
            let loss = backend.train_step(ctx, state, quant_on, step_lr)?;
            steps_run += 1;
            if !loss.is_finite() {
                if opts.every > 0 && rollbacks < opts.max_rollbacks {
                    rollbacks += 1;
                    lr_scale *= opts.backoff;
                    let meta = self.try_adopt_checkpoint(&opts.tag, steps)?.ok_or_else(|| {
                        anyhow!(
                            "divergence rollback: checkpoint `{}` disappeared from {}",
                            opts.tag,
                            self.dir.display()
                        )
                    })?;
                    s = meta.steps_into_run;
                    recent.clear();
                    crate::info!(
                        "{}: diverged (loss = {loss}); rolled back to step {s} with lr scale \
                         {lr_scale:.3e} (rollback {rollbacks}/{})",
                        self.spec.name,
                        opts.max_rollbacks
                    );
                    // Persist the reduced lr so a kill right after the
                    // rollback resumes with the same policy.
                    self.save_checkpoint(&opts.tag, steps, s, lr_scale, rollbacks)?;
                    continue;
                }
                if opts.every > 0 {
                    bail!(
                        "training diverged at step {s} (loss = {loss}) after {rollbacks} \
                         rollback(s); giving up"
                    );
                }
                bail!("training diverged at step {s} (loss = {loss})");
            }
            recent.push(loss);
            if recent.len() > 10 {
                recent.remove(0);
            }
            s += 1;
            if opts.every > 0 && s < steps && s % opts.every == 0 {
                self.save_checkpoint(&opts.tag, steps, s, lr_scale, rollbacks)?;
            }
        }
        if opts.every > 0 {
            let _ = std::fs::remove_file(self.checkpoint_path(&opts.tag));
        }
        Ok(TrainProgress {
            completed: true,
            loss: recent.iter().sum::<f32>() / recent.len().max(1) as f32,
            steps_run,
            at_step: s,
            rollbacks,
            resumed,
        })
    }

    // -- training checkpoints ------------------------------------------------

    /// Path of the training checkpoint for `tag`.
    pub fn checkpoint_path(&self, tag: &str) -> PathBuf {
        self.dir.join(format!("ckpt.{tag}.bin"))
    }

    /// Serialize the full mutable training state under `tag`:
    /// `u32 meta_len · meta JSON · act_scales · params · momentum` (all
    /// f32 little-endian, wrapped in a checksummed artifact so partial
    /// writes and bit-rot are detected at load).
    fn save_checkpoint(
        &self,
        tag: &str,
        run_total: usize,
        steps_into_run: usize,
        lr_scale: f32,
        rollbacks: u32,
    ) -> Result<()> {
        use crate::util::json::Json;
        let elems = self.spec.n_param_elems();
        let meta = Json::obj(vec![
            ("version", Json::num(1.0)),
            ("model", Json::str(&self.spec.name)),
            ("run_total", Json::num(run_total as f64)),
            ("steps_into_run", Json::num(steps_into_run as f64)),
            // u64 counters as strings: JSON f64 would lose >2^53.
            ("steps_done", Json::str(&self.steps_done.to_string())),
            ("data_seed", Json::str(&self.data_seed.to_string())),
            ("lr_scale_bits", Json::num(lr_scale.to_bits() as f64)),
            ("rollbacks", Json::num(rollbacks as f64)),
            ("elems", Json::num(elems as f64)),
            ("n_q", Json::num(self.spec.n_q as f64)),
        ])
        .to_string();
        let meta_b = meta.as_bytes();
        let mut payload =
            Vec::with_capacity(4 + meta_b.len() + 4 * (self.spec.n_q + 2 * elems));
        payload.extend_from_slice(&(meta_b.len() as u32).to_le_bytes());
        payload.extend_from_slice(meta_b);
        for &v in &self.act_scales {
            payload.extend_from_slice(&v.to_le_bytes());
        }
        for t in &self.params {
            for &v in t {
                payload.extend_from_slice(&v.to_le_bytes());
            }
        }
        for t in &self.mom {
            for &v in t {
                payload.extend_from_slice(&v.to_le_bytes());
            }
        }
        crate::util::artifact::write_atomic(&self.checkpoint_path(tag), &payload)
            .with_context(|| format!("saving checkpoint `{tag}`"))
    }

    /// Adopt the checkpoint for `tag` if one exists and belongs to this
    /// run (same model, total step count, data seed, param layout):
    /// restores params/momentum/scales/step-counter bit-exactly and
    /// returns its loop-control meta.  `Ok(None)` when absent or for a
    /// different run; `Err` (with path + reason) when the file exists
    /// but is corrupt — a bad checkpoint is never silently consumed.
    fn try_adopt_checkpoint(&mut self, tag: &str, run_total: usize) -> Result<Option<CkptMeta>> {
        use crate::util::json::Json;
        let path = self.checkpoint_path(tag);
        if !path.exists() {
            return Ok(None);
        }
        let payload = crate::util::artifact::load(&path)?;
        let fail = |why: String| anyhow!("checkpoint {}: {why}", path.display());
        if payload.len() < 4 {
            return Err(fail("truncated (no meta length)".into()));
        }
        let meta_len =
            u32::from_le_bytes([payload[0], payload[1], payload[2], payload[3]]) as usize;
        if payload.len() < 4 + meta_len {
            return Err(fail(format!(
                "truncated meta block ({} bytes present, {meta_len} declared)",
                payload.len() - 4
            )));
        }
        let meta_str = std::str::from_utf8(&payload[4..4 + meta_len])
            .map_err(|_| fail("meta is not UTF-8".into()))?;
        let meta =
            Json::parse(meta_str).map_err(|e| fail(format!("meta does not parse: {e}")))?;
        let model = meta.get("model").and_then(Json::as_str).unwrap_or("");
        let elems_meta = meta.get("elems").and_then(Json::as_usize).unwrap_or(0);
        let run_total_meta = meta
            .get("run_total")
            .and_then(Json::as_usize)
            .unwrap_or(usize::MAX);
        let seed_meta = meta
            .get("data_seed")
            .and_then(Json::as_str)
            .and_then(|s| s.parse::<u64>().ok());
        let elems = self.spec.n_param_elems();
        if model != self.spec.name
            || run_total_meta != run_total
            || elems_meta != elems
            || seed_meta != Some(self.data_seed)
        {
            crate::info!(
                "checkpoint {} belongs to a different run (model/steps/seed mismatch); ignoring",
                path.display()
            );
            return Ok(None);
        }
        let n_q = self.spec.n_q;
        let want = 4 + meta_len + 4 * (n_q + 2 * elems);
        if payload.len() != want {
            return Err(fail(format!(
                "payload is {} bytes, expected {want} ({elems} param elems × 2 + {n_q} scales)",
                payload.len()
            )));
        }
        let mut off = 4 + meta_len;
        let mut read_f32s = |n: usize| -> Vec<f32> {
            let mut v = Vec::with_capacity(n);
            for i in 0..n {
                let b = &payload[off + i * 4..off + i * 4 + 4];
                v.push(f32::from_le_bytes([b[0], b[1], b[2], b[3]]));
            }
            off += n * 4;
            v
        };
        let scales = read_f32s(n_q);
        let tensor_sizes: Vec<usize> = self.spec.params.iter().map(|p| p.numel()).collect();
        let params: Vec<Vec<f32>> = tensor_sizes.iter().map(|&n| read_f32s(n)).collect();
        let mom: Vec<Vec<f32>> = tensor_sizes.iter().map(|&n| read_f32s(n)).collect();
        let steps_done = meta
            .get("steps_done")
            .and_then(Json::as_str)
            .and_then(|v| v.parse::<u64>().ok())
            .ok_or_else(|| fail("missing steps_done".into()))?;
        let steps_into_run = meta
            .get("steps_into_run")
            .and_then(Json::as_usize)
            .ok_or_else(|| fail("missing steps_into_run".into()))?;
        let lr_scale = f32::from_bits(
            meta.get("lr_scale_bits")
                .and_then(Json::as_f64)
                .unwrap_or(1.0f32.to_bits() as f64) as u32,
        );
        let rollbacks = meta.get("rollbacks").and_then(Json::as_usize).unwrap_or(0) as u32;
        self.act_scales = scales;
        self.params = params;
        self.mom = mom;
        self.steps_done = steps_done;
        Ok(Some(CkptMeta {
            steps_into_run,
            lr_scale,
            rollbacks,
        }))
    }

    /// Snapshot the full mutable training state under `tag` — the
    /// schedule journal's oracle-state persistence hook.
    pub fn save_state_snapshot(&self, tag: &str) -> Result<()> {
        self.save_checkpoint(tag, 0, 0, 1.0, 0)
    }

    /// Restore a [`Self::save_state_snapshot`].  `Ok(false)` when no
    /// snapshot exists for `tag`; `Err` when one exists but is corrupt.
    pub fn load_state_snapshot(&mut self, tag: &str) -> Result<bool> {
        Ok(self.try_adopt_checkpoint(tag, 0)?.is_some())
    }

    /// Delete the snapshot stored for `tag`, if any — cleanup for
    /// content-addressed schedule-search snapshots that can no longer
    /// be served (e.g. a session-only accuracy cache was discarded).
    pub fn drop_state_snapshot(&self, tag: &str) {
        let _ = std::fs::remove_file(self.checkpoint_path(tag));
    }

    /// Accuracy over `n_batches` of the given split (batch = spec eval
    /// batch).  Returns fraction correct.
    pub fn evaluate(
        &mut self,
        state: &CompressionState,
        quant_on: bool,
        split: Split,
        n_batches: usize,
    ) -> Result<f64> {
        let (backend, ctx) = self.ctx();
        backend.evaluate(ctx, state, quant_on, split, n_batches)
    }

    /// Logits for a raw input batch (must match `batch_logits`).
    pub fn logits(
        &mut self,
        state: &CompressionState,
        quant_on: bool,
        x: &[f32],
    ) -> Result<Vec<f32>> {
        let (backend, ctx) = self.ctx();
        backend.logits(ctx, state, quant_on, x)
    }

    /// Calibrate activation scales over `n_batches` of train data;
    /// stores and returns the scales.
    pub fn calibrate(&mut self, n_batches: usize) -> Result<Vec<f32>> {
        let (backend, ctx) = self.ctx();
        backend.calibrate(ctx, n_batches)
    }

    /// Native mirror of the AOT calib recipe ([`calibrate_scales`]),
    /// regardless of the active backend — no PJRT required.  Stores and
    /// returns the scales.
    pub fn calibrate_native(&mut self, n_batches: usize, threads: usize) -> Vec<f32> {
        self.act_scales =
            calibrate_scales(&self.spec, &self.params, self.data_seed, n_batches, threads);
        self.act_scales.clone()
    }

    /// Persist current params next to the artifacts (checkpointing).
    pub fn save_params(&self, tag: &str) -> Result<PathBuf> {
        std::fs::create_dir_all(&self.dir)
            .with_context(|| format!("creating {}", self.dir.display()))?;
        let path = self.dir.join(format!("params.{tag}.bin"));
        let p = Params {
            tensors: self.params.clone(),
        };
        p.save(&self.spec, &path).context("save params")?;
        Ok(path)
    }

    /// Load params from a checkpoint produced by [`save_params`].
    pub fn load_params(&mut self, tag: &str) -> Result<bool> {
        let path = self.dir.join(format!("params.{tag}.bin"));
        if !path.exists() {
            return Ok(false);
        }
        let p = Params::load(&self.spec, &path)?;
        self.params = p.tensors;
        Ok(true)
    }
}

/// Standalone tile-kernel cross-check: run `artifacts/tile_matmul.hlo.txt`
/// (the Pallas systolic kernel) on (128,192)×(192,128) operands.
pub fn run_tile_kernel(artifacts_dir: &Path, x: &[f32], w: &[f32]) -> Result<Vec<f32>> {
    assert_eq!(x.len(), 128 * 192);
    assert_eq!(w.len(), 192 * 128);
    let client = PjRtClient::cpu().map_err(|e| anyhow!("pjrt: {e:?}"))?;
    let proto = HloModuleProto::from_text_file(artifacts_dir.join("tile_matmul.hlo.txt"))
        .map_err(|e| anyhow!("tile hlo: {e:?}"))?;
    let exe = client
        .compile(&XlaComputation::from_proto(&proto))
        .map_err(|e| anyhow!("tile compile: {e:?}"))?;
    let xl = lit_f32(x, &[128, 192])?;
    let wl = lit_f32(w, &[192, 128])?;
    let result = exe
        .execute::<Literal>(&[xl, wl])
        .map_err(|e| anyhow!("tile exec: {e:?}"))?[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow!("tile sync: {e:?}"))?;
    let out = result.to_tuple1().map_err(|e| anyhow!("tile tuple: {e:?}"))?;
    out.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))
}
