//! PJRT runtime: loads the AOT HLO-text artifacts and drives
//! training / evaluation / calibration from the Rust hot path.
//!
//! Python never runs here — the artifacts under `artifacts/<model>/` are
//! compiled once by `PjRtClient` and then executed with concrete inputs.
//! Interchange is HLO *text* (xla_extension 0.5.1 rejects jax ≥ 0.5
//! serialized protos — see DESIGN.md / aot.py).

use crate::data::{self, Split};
use crate::model::{ModelSpec, Params};
use crate::quant::{magnitude_mask, KSET, SET_SENTINEL};
use crate::selection::CompressionState;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

/// Learning-rate schedule for the training driver.
#[derive(Clone, Copy, Debug)]
pub struct LrSchedule {
    pub base: f32,
    /// Step fraction after which lr drops by 5×.
    pub decay_at: f32,
}

impl Default for LrSchedule {
    fn default() -> Self {
        Self {
            base: 0.01,
            decay_at: 0.75,
        }
    }
}

/// A loaded model: spec + compiled executables + resident parameters.
pub struct ModelRuntime {
    pub spec: ModelSpec,
    client: PjRtClient,
    exes: HashMap<String, PjRtLoadedExecutable>,
    dir: PathBuf,
    /// Float shadow parameters (updated by train steps).
    pub params: Vec<Vec<f32>>,
    /// Momentum buffers.
    mom: Vec<Vec<f32>>,
    /// Per-quant-point activation scales (0 until calibrated).
    pub act_scales: Vec<f32>,
    /// Dataset seed (shared with data generation everywhere).
    pub data_seed: u64,
    /// Executed-step counter (drives the train-data cursor).
    pub steps_done: u64,
}

impl ModelRuntime {
    /// Load manifest + initial params and connect the PJRT CPU client.
    /// Executables compile lazily on first use.
    pub fn load(artifacts_dir: &Path, model: &str) -> Result<Self> {
        let dir = artifacts_dir.join(model);
        let spec = ModelSpec::from_manifest_file(&dir.join("manifest.json"))?;
        let params = Params::load(&spec, &dir.join("params.bin"))?;
        let client = PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        let mom = spec.params.iter().map(|p| vec![0.0f32; p.numel()]).collect();
        let n_q = spec.n_q;
        Ok(Self {
            spec,
            client,
            exes: HashMap::new(),
            dir,
            params: params.tensors,
            mom,
            act_scales: vec![0.0; n_q],
            data_seed: 7,
            steps_done: 0,
        })
    }

    fn exe(&mut self, entry: &str) -> Result<&PjRtLoadedExecutable> {
        if !self.exes.contains_key(entry) {
            let meta = self
                .spec
                .entries
                .iter()
                .find(|(n, _)| n == entry)
                .map(|(_, m)| m.clone())
                .ok_or_else(|| anyhow!("no entry `{entry}` in manifest"))?;
            let path = self.dir.join(&meta.file);
            let proto = HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
            let comp = XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {entry}: {e:?}"))?;
            crate::info!(
                "compiled {}/{} ({} inputs)",
                self.spec.name,
                entry,
                meta.n_inputs
            );
            self.exes.insert(entry.to_string(), exe);
        }
        Ok(self.exes.get(entry).unwrap())
    }

    // -- literal helpers ----------------------------------------------------

    fn lit_f32(data: &[f32], dims: &[i64]) -> Result<Literal> {
        Literal::vec1(data)
            .reshape(dims)
            .map_err(|e| anyhow!("reshape {dims:?}: {e:?}"))
    }

    fn lit_scalar(v: f32) -> Literal {
        Literal::scalar(v)
    }

    /// Materialize per-conv masks from the *current* params under
    /// `state` (pruned weights receive no gradient, so recomputation is
    /// stable across fine-tune steps).
    pub fn masks_for(&self, state: &CompressionState) -> Vec<Vec<f32>> {
        let convs = self.spec.convs();
        convs
            .iter()
            .map(|c| {
                let ratio = state.layers[c.conv_idx].prune_ratio;
                if ratio <= 0.0 {
                    vec![1.0f32; self.params[c.w].len()]
                } else {
                    magnitude_mask(&self.params[c.w], ratio)
                }
            })
            .collect()
    }

    fn wset_tables(&self, state: &CompressionState) -> (Vec<[f32; KSET]>, Vec<f32>) {
        let mut tables = Vec::with_capacity(self.spec.n_conv);
        let mut on = Vec::with_capacity(self.spec.n_conv);
        for l in &state.layers {
            match &l.wset {
                Some(s) => {
                    tables.push(s.padded_table());
                    on.push(1.0f32);
                }
                None => {
                    tables.push([SET_SENTINEL; KSET]);
                    on.push(0.0f32);
                }
            }
        }
        (tables, on)
    }

    /// Common input prefix for eval/logits: params, masks, wsets,
    /// wset_on, act_scales, quant_on.
    fn common_inputs(
        &self,
        state: &CompressionState,
        quant_on: bool,
    ) -> Result<Vec<Literal>> {
        let mut ins = Vec::new();
        for (t, p) in self.params.iter().zip(&self.spec.params) {
            let dims: Vec<i64> = p.shape.iter().map(|&d| d as i64).collect();
            ins.push(Self::lit_f32(t, &dims)?);
        }
        let masks = self.masks_for(state);
        for (m, c) in masks.iter().zip(self.spec.convs()) {
            let p = &self.spec.params[c.w];
            let dims: Vec<i64> = p.shape.iter().map(|&d| d as i64).collect();
            ins.push(Self::lit_f32(m, &dims)?);
        }
        let (tables, on) = self.wset_tables(state);
        for t in &tables {
            ins.push(Self::lit_f32(t, &[KSET as i64])?);
        }
        ins.push(Self::lit_f32(&on, &[self.spec.n_conv as i64])?);
        ins.push(Self::lit_f32(&self.act_scales, &[self.spec.n_q as i64])?);
        ins.push(Self::lit_scalar(if quant_on { 1.0 } else { 0.0 }));
        Ok(ins)
    }

    fn batch_literals(&self, split: Split, start: u64, size: usize) -> Result<(Literal, Literal)> {
        let (xs, ys) = data::batch(self.data_seed, split, start, size, self.spec.n_classes as u64);
        let x = Self::lit_f32(&xs, &[size as i64, 32, 32, 3])?;
        let y = Literal::vec1(&ys);
        Ok((x, y))
    }

    // -- drivers -------------------------------------------------------------

    /// Run `steps` SGD+momentum steps.  Returns the mean loss of the
    /// final 10 steps.
    pub fn train_steps(
        &mut self,
        state: &CompressionState,
        quant_on: bool,
        lr: LrSchedule,
        steps: usize,
    ) -> Result<f32> {
        let bs = self.spec.batch_train;
        let n_p = self.spec.params.len();
        let mut recent = Vec::new();
        for s in 0..steps {
            let step_lr = if (s as f32) < lr.decay_at * steps as f32 {
                lr.base
            } else {
                lr.base / 5.0
            };
            let cursor = self.steps_done * bs as u64;
            let (x, y) = self.batch_literals(Split::Train, cursor, bs)?;

            let mut ins: Vec<Literal> = Vec::new();
            for (t, p) in self.params.iter().zip(&self.spec.params) {
                let dims: Vec<i64> = p.shape.iter().map(|&d| d as i64).collect();
                ins.push(Self::lit_f32(t, &dims)?);
            }
            for (t, p) in self.mom.iter().zip(&self.spec.params) {
                let dims: Vec<i64> = p.shape.iter().map(|&d| d as i64).collect();
                ins.push(Self::lit_f32(t, &dims)?);
            }
            let masks = self.masks_for(state);
            for (m, c) in masks.iter().zip(self.spec.convs()) {
                let p = &self.spec.params[c.w];
                let dims: Vec<i64> = p.shape.iter().map(|&d| d as i64).collect();
                ins.push(Self::lit_f32(m, &dims)?);
            }
            let (tables, on) = self.wset_tables(state);
            for t in &tables {
                ins.push(Self::lit_f32(t, &[KSET as i64])?);
            }
            ins.push(Self::lit_f32(&on, &[self.spec.n_conv as i64])?);
            ins.push(Self::lit_f32(&self.act_scales, &[self.spec.n_q as i64])?);
            ins.push(Self::lit_scalar(if quant_on { 1.0 } else { 0.0 }));
            ins.push(Self::lit_scalar(step_lr));
            ins.push(x);
            ins.push(y);

            let exe = self.exe("train")?;
            let result = exe
                .execute::<Literal>(&ins)
                .map_err(|e| anyhow!("train exec: {e:?}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("train sync: {e:?}"))?;
            let outs = result.to_tuple().map_err(|e| anyhow!("train tuple: {e:?}"))?;
            if outs.len() != 2 * n_p + 1 {
                bail!("train output arity {} != {}", outs.len(), 2 * n_p + 1);
            }
            for (i, o) in outs.iter().enumerate().take(n_p) {
                self.params[i] = o.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
            }
            for i in 0..n_p {
                self.mom[i] = outs[n_p + i]
                    .to_vec::<f32>()
                    .map_err(|e| anyhow!("{e:?}"))?;
            }
            let loss: f32 = outs[2 * n_p]
                .get_first_element()
                .map_err(|e| anyhow!("{e:?}"))?;
            if !loss.is_finite() {
                bail!("training diverged at step {s} (loss = {loss})");
            }
            recent.push(loss);
            if recent.len() > 10 {
                recent.remove(0);
            }
            self.steps_done += 1;
        }
        Ok(recent.iter().sum::<f32>() / recent.len().max(1) as f32)
    }

    /// Accuracy over `n_batches` of the given split (batch = spec eval
    /// batch).  Returns fraction correct.
    pub fn evaluate(
        &mut self,
        state: &CompressionState,
        quant_on: bool,
        split: Split,
        n_batches: usize,
    ) -> Result<f64> {
        let bs = self.spec.batch_eval;
        let mut correct = 0.0f64;
        for b in 0..n_batches {
            let mut ins = self.common_inputs(state, quant_on)?;
            let (x, y) = self.batch_literals(split, (b * bs) as u64, bs)?;
            ins.push(x);
            ins.push(y);
            let exe = self.exe("eval")?;
            let result = exe
                .execute::<Literal>(&ins)
                .map_err(|e| anyhow!("eval exec: {e:?}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("eval sync: {e:?}"))?;
            let (nc, _loss) = result
                .to_tuple2()
                .map_err(|e| anyhow!("eval tuple: {e:?}"))?;
            let nc: f32 = nc.get_first_element().map_err(|e| anyhow!("{e:?}"))?;
            correct += nc as f64;
        }
        Ok(correct / (n_batches * bs) as f64)
    }

    /// Logits for a raw input batch (must match `batch_logits`).
    pub fn logits(
        &mut self,
        state: &CompressionState,
        quant_on: bool,
        x: &[f32],
    ) -> Result<Vec<f32>> {
        let bs = self.spec.batch_logits;
        assert_eq!(x.len(), bs * 32 * 32 * 3);
        let mut ins = self.common_inputs(state, quant_on)?;
        ins.push(Self::lit_f32(x, &[bs as i64, 32, 32, 3])?);
        let exe = self.exe("logits")?;
        let result = exe
            .execute::<Literal>(&ins)
            .map_err(|e| anyhow!("logits exec: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("logits sync: {e:?}"))?;
        let out = result
            .to_tuple1()
            .map_err(|e| anyhow!("logits tuple: {e:?}"))?;
        out.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))
    }

    /// Calibrate activation scales over `n_batches` of train data using
    /// the AOT `calib` graph; stores and returns the scales.
    pub fn calibrate(&mut self, n_batches: usize) -> Result<Vec<f32>> {
        let bs = self.spec.batch_calib;
        let mut maxes = vec![0.0f32; self.spec.n_q];
        for b in 0..n_batches {
            let mut ins: Vec<Literal> = Vec::new();
            for (t, p) in self.params.iter().zip(&self.spec.params) {
                let dims: Vec<i64> = p.shape.iter().map(|&d| d as i64).collect();
                ins.push(Self::lit_f32(t, &dims)?);
            }
            let (x, _y) = self.batch_literals(Split::Train, (b * bs) as u64, bs)?;
            ins.push(x);
            let exe = self.exe("calib")?;
            let result = exe
                .execute::<Literal>(&ins)
                .map_err(|e| anyhow!("calib exec: {e:?}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("calib sync: {e:?}"))?;
            let (out, _logit_mean) = result
                .to_tuple2()
                .map_err(|e| anyhow!("calib tuple: {e:?}"))?;
            let v = out.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
            for (m, x) in maxes.iter_mut().zip(&v) {
                *m = m.max(*x);
            }
        }
        self.act_scales = maxes
            .iter()
            .map(|&m| (m / crate::quant::QMAX as f32).max(1e-9))
            .collect();
        Ok(self.act_scales.clone())
    }

    /// Native mirror of [`Self::calibrate`]: the same data recipe
    /// (train split, `batch_calib`-sized batches from offset 0) through
    /// the compiled float engine
    /// ([`crate::model::ParallelEngine::calibrate`]) instead of the AOT
    /// `calib` graph — one forward scratch per worker reused across the
    /// whole batch loop, no PJRT required.  Stores and returns the
    /// scales, exactly like the AOT path.
    pub fn calibrate_native(&mut self, n_batches: usize, threads: usize) -> Vec<f32> {
        let bs = self.spec.batch_calib;
        let qc = crate::model::QuantConfig::float(&self.spec);
        let eng = crate::model::ParallelEngine::new(&self.spec, &self.params, &qc, threads);
        let batches: Vec<Vec<f32>> = (0..n_batches)
            .map(|b| {
                data::batch(
                    self.data_seed,
                    Split::Train,
                    (b * bs) as u64,
                    bs,
                    self.spec.n_classes as u64,
                )
                .0
            })
            .collect();
        let refs: Vec<&[f32]> = batches.iter().map(Vec::as_slice).collect();
        self.act_scales = eng.calibrate(&refs, bs);
        self.act_scales.clone()
    }

    /// Persist current params next to the artifacts (checkpointing).
    pub fn save_params(&self, tag: &str) -> Result<PathBuf> {
        let path = self.dir.join(format!("params.{tag}.bin"));
        let p = Params {
            tensors: self.params.clone(),
        };
        p.save(&self.spec, &path).context("save params")?;
        Ok(path)
    }

    /// Load params from a checkpoint produced by [`save_params`].
    pub fn load_params(&mut self, tag: &str) -> Result<bool> {
        let path = self.dir.join(format!("params.{tag}.bin"));
        if !path.exists() {
            return Ok(false);
        }
        let p = Params::load(&self.spec, &path)?;
        self.params = p.tensors;
        Ok(true)
    }
}

/// Standalone tile-kernel cross-check: run `artifacts/tile_matmul.hlo.txt`
/// (the Pallas systolic kernel) on (128,192)×(192,128) operands.
pub fn run_tile_kernel(artifacts_dir: &Path, x: &[f32], w: &[f32]) -> Result<Vec<f32>> {
    assert_eq!(x.len(), 128 * 192);
    assert_eq!(w.len(), 192 * 128);
    let client = PjRtClient::cpu().map_err(|e| anyhow!("pjrt: {e:?}"))?;
    let proto = HloModuleProto::from_text_file(artifacts_dir.join("tile_matmul.hlo.txt"))
        .map_err(|e| anyhow!("tile hlo: {e:?}"))?;
    let exe = client
        .compile(&XlaComputation::from_proto(&proto))
        .map_err(|e| anyhow!("tile compile: {e:?}"))?;
    let xl = ModelRuntime::lit_f32(x, &[128, 192])?;
    let wl = ModelRuntime::lit_f32(w, &[192, 128])?;
    let result = exe
        .execute::<Literal>(&[xl, wl])
        .map_err(|e| anyhow!("tile exec: {e:?}"))?[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow!("tile sync: {e:?}"))?;
    let out = result.to_tuple1().map_err(|e| anyhow!("tile tuple: {e:?}"))?;
    out.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))
}
