//! The native training/evaluation backend: pure Rust, no artifacts, no
//! PJRT — and batch-parallel, which makes the §4.3 schedule's accuracy
//! oracle (the dominant end-to-end cost) a multi-threaded hot path
//! instead of a serial stub.
//!
//! Driver semantics mirror the AOT graphs:
//!
//! * `train_step` — one SGD+momentum QAT step through
//!   [`crate::model::GradEngine`] (fake-quant forward, STE backward):
//!   per-step mask recomputation from the float shadow weights and the
//!   train-data cursor at `steps_done · batch_train` — the surrounding
//!   loop (lr decay, divergence bail-out, loss window) lives in the
//!   facade, shared with the AOT backend by construction.  Per-image
//!   gradients reduce in fixed image order, so parameters are
//!   **bit-identical at any thread count** (pinned in
//!   `rust/tests/native_backend.rs`).
//! * `evaluate` / `logits` — the int8 mirror engine
//!   ([`crate::model::ParallelEngine`], exact i32 accumulation, pinned
//!   against the AOT `logits` graph) when `quant_on`; the fake-quant
//!   float forward of the grad engine otherwise (matching the AOT
//!   eval graph, whose weights are always fake-quantized).
//! * `calibrate` — the PJRT-free mirror of the AOT calib recipe (same
//!   data recipe through the compiled float engine, max-merged per
//!   worker), exactly [`super::ModelRuntime::calibrate_native`].

use super::{Backend, RtCtx};
use crate::data::{self, Split};
use crate::model::infer::Forward;
use crate::model::{GradEngine, ModelSpec, ParallelEngine, QuantConfig};
use crate::selection::CompressionState;
use anyhow::Result;

/// The pure-Rust backend.  Stateless: all runtime state lives in the
/// facade and arrives through [`RtCtx`].
#[derive(Default)]
pub struct NativeBackend;

/// Quantization config for the current params under `state`: the
/// shared per-conv mask recipe ([`super::mask_options`] — one source of
/// truth with the AOT literal path) and the state's restricted weight
/// sets.  `quant_on` gates activation quantization only — weights are
/// always fake-quantized by the engines this feeds.
fn qc_for(
    spec: &ModelSpec,
    params: &[Vec<f32>],
    act_scales: &[f32],
    state: &CompressionState,
    quant_on: bool,
) -> QuantConfig {
    let mut wsets = vec![None; spec.n_conv];
    for c in spec.convs() {
        wsets[c.conv_idx] = state.layers[c.conv_idx].wset.clone();
    }
    QuantConfig {
        act_scales: act_scales.to_vec(),
        quant_on,
        masks: super::mask_options(spec, params, state),
        wsets,
    }
}

/// Wrap raw logits in a [`Forward`] so accuracy counting reuses the
/// documented lowest-index-tie-break `Forward::argmax` instead of a
/// second copy of the rule.
fn as_forward(logits: Vec<f32>, batch: usize) -> Forward {
    Forward {
        logits,
        batch,
        act_max: Vec::new(),
        captures: Vec::new(),
    }
}

/// Correct predictions of one forward batch.
fn count_correct(fwd: &Forward, y: &[i32]) -> usize {
    y.iter()
        .enumerate()
        .filter(|(i, &yi)| fwd.argmax(*i) == yi as usize)
        .count()
}

impl NativeBackend {
    /// Logits for a batch under `state`: int8 mirror when `quant_on`,
    /// fake-quant float forward otherwise.
    fn batch_logits(
        ctx: &RtCtx<'_>,
        state: &CompressionState,
        quant_on: bool,
        x: &[f32],
        batch: usize,
    ) -> Result<Vec<f32>> {
        let qc = qc_for(
            ctx.spec,
            ctx.params.as_slice(),
            ctx.act_scales.as_slice(),
            state,
            quant_on,
        );
        if quant_on {
            let eng = ParallelEngine::new(ctx.spec, ctx.params.as_slice(), &qc, ctx.threads);
            // A worker panic surfaces as a structured PoisonedBatch
            // error (naming the poisoned image indices) instead of
            // tearing the process down mid-pipeline.
            Ok(eng.try_forward_plain(x, batch)?.logits)
        } else {
            let eng = GradEngine::new(ctx.spec, ctx.params.as_slice(), &qc, true);
            Ok(eng.forward_batch(ctx.params.as_slice(), x, batch, ctx.threads))
        }
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn train_step(
        &mut self,
        ctx: RtCtx<'_>,
        state: &CompressionState,
        quant_on: bool,
        step_lr: f32,
    ) -> Result<f32> {
        let spec = ctx.spec;
        let bs = spec.batch_train;
        let cursor = *ctx.steps_done * bs as u64;
        let (x, y) = data::batch(
            ctx.data_seed,
            Split::Train,
            cursor,
            bs,
            spec.n_classes as u64,
        );
        // Masks and weight quantization track the current float shadow
        // weights — rebuild the engine every step, exactly like the AOT
        // graph recomputes them inside the step.
        let (loss, grads) = {
            let qc = qc_for(
                spec,
                ctx.params.as_slice(),
                ctx.act_scales.as_slice(),
                state,
                quant_on,
            );
            let eng = GradEngine::new(spec, ctx.params.as_slice(), &qc, true);
            eng.batch_grad(ctx.params.as_slice(), &x, &y, ctx.threads)
        };
        // Momentum comes from the spec (the same value the AOT graph
        // was lowered with), not a native-side constant.
        let momentum = spec.momentum;
        for (i, g) in grads.iter().enumerate() {
            let mom = &mut ctx.mom[i];
            let pt = &mut ctx.params[i];
            for ((m, p), &gv) in mom.iter_mut().zip(pt.iter_mut()).zip(g.iter()) {
                *m = momentum * *m + gv;
                *p -= step_lr * *m;
            }
        }
        *ctx.steps_done += 1;
        Ok(loss)
    }

    fn evaluate(
        &mut self,
        ctx: RtCtx<'_>,
        state: &CompressionState,
        quant_on: bool,
        split: Split,
        n_batches: usize,
    ) -> Result<f64> {
        let spec = ctx.spec;
        let bs = spec.batch_eval;
        let ncls = spec.n_classes as u64;
        // Params and state are frozen across the whole loop: build the
        // quant config (mask sort) and compile the engine once, not per
        // batch — this is the oracle hot path.
        let qc = qc_for(
            spec,
            ctx.params.as_slice(),
            ctx.act_scales.as_slice(),
            state,
            quant_on,
        );
        let mut correct = 0usize;
        if quant_on {
            let eng = ParallelEngine::new(spec, ctx.params.as_slice(), &qc, ctx.threads);
            for b in 0..n_batches {
                let (x, y) = data::batch(ctx.data_seed, split, (b * bs) as u64, bs, ncls);
                correct += count_correct(&eng.try_forward_plain(&x, bs)?, &y);
            }
        } else {
            let eng = GradEngine::new(spec, ctx.params.as_slice(), &qc, true);
            for b in 0..n_batches {
                let (x, y) = data::batch(ctx.data_seed, split, (b * bs) as u64, bs, ncls);
                let logits = eng.forward_batch(ctx.params.as_slice(), &x, bs, ctx.threads);
                correct += count_correct(&as_forward(logits, bs), &y);
            }
        }
        Ok(correct as f64 / (n_batches * bs) as f64)
    }

    fn logits(
        &mut self,
        ctx: RtCtx<'_>,
        state: &CompressionState,
        quant_on: bool,
        x: &[f32],
    ) -> Result<Vec<f32>> {
        let bs = ctx.spec.batch_logits;
        assert_eq!(x.len(), bs * 32 * 32 * 3);
        Self::batch_logits(&ctx, state, quant_on, x, bs)
    }

    fn calibrate(&mut self, ctx: RtCtx<'_>, n_batches: usize) -> Result<Vec<f32>> {
        *ctx.act_scales = super::calibrate_scales(
            ctx.spec,
            ctx.params.as_slice(),
            ctx.data_seed,
            n_batches,
            ctx.threads,
        );
        Ok(ctx.act_scales.clone())
    }
}
